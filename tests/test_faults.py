"""Fault-model registry: listing/error mechanics, spec round-trips,
determinism of every schedule, transient classification, and the recovery
primitives (backoff jitter, circuit breaker, deadline watchdog) that consume
the injected faults."""

import pytest

from repro.core import faults
from repro.serve import recovery

# ---------------------------------------------------------------------------
# Registry mechanics.
# ---------------------------------------------------------------------------


def test_registry_contents_and_errors():
    names = faults.available_faults()
    for expected in ("none", "transient_executor", "worker_crash",
                     "compile_failure", "nan_poison", "slow_batch", "chaos"):
        assert expected in names
    assert names == tuple(sorted(names))
    with pytest.raises(ValueError, match="unknown fault model"):
        faults.get_fault("nope")
    with pytest.raises(ValueError, match="unknown fault model"):
        faults.fault_from_spec({"fault_model": "nope"})


def test_bad_params_fail_at_construction():
    with pytest.raises(ValueError, match="failures"):
        faults.get_fault("transient_executor")(failures=-1)
    with pytest.raises(ValueError, match="crashes"):
        faults.get_fault("worker_crash")(crashes=-2)
    with pytest.raises(ValueError, match="count"):
        faults.get_fault("nan_poison")(count=-1)
    with pytest.raises(ValueError, match="delay_s"):
        faults.get_fault("slow_batch")(delay_s=-0.1)
    with pytest.raises(ValueError, match="poison"):
        faults.get_fault("chaos")(poison=-1)
    with pytest.raises(TypeError):
        faults.get_fault("nan_poison")(not_a_param=3)


def test_spec_round_trip_every_entry():
    built = {
        "none": faults.NoFault(seed=7),
        "transient_executor": faults.get_fault("transient_executor")(
            seed=1, failures=2),
        "worker_crash": faults.get_fault("worker_crash")(
            seed=2, crashes=0, crash_round=5),
        "compile_failure": faults.get_fault("compile_failure")(seed=3),
        "nan_poison": faults.get_fault("nan_poison")(seed=4, count=2),
        "slow_batch": faults.get_fault("slow_batch")(
            seed=5, delay_s=0.01, slow_attempts=3),
        "chaos": faults.get_fault("chaos")(seed=6, delay_s=0.02, poison=2),
    }
    assert set(built) == set(faults.available_faults())
    for name, model in built.items():
        spec = model.spec()
        assert spec["fault_model"] == name == type(model).fault_name
        clone = faults.fault_from_spec(spec)
        assert type(clone) is type(model)
        assert clone.spec() == spec
        # JSON-scalar params only (the serve CLI passes them as JSON).
        for v in spec["fault_params"].values():
            assert v is None or isinstance(v, (int, float, str, bool))


def test_transient_classification():
    assert faults.WorkerCrashError("x").transient
    assert faults.TransientExecutorError("x").transient
    assert not faults.CompileFailureError("x").transient
    assert not faults.InjectedFault("x").transient
    assert recovery.is_transient(faults.WorkerCrashError("x"))
    assert not recovery.is_transient(faults.CompileFailureError("x"))
    assert not recovery.is_transient(RuntimeError("plain"))
    for err in (faults.WorkerCrashError, faults.TransientExecutorError,
                faults.CompileFailureError):
        assert issubclass(err, faults.InjectedFault)
        assert issubclass(err, RuntimeError)


# ---------------------------------------------------------------------------
# Schedule determinism.
# ---------------------------------------------------------------------------


def test_key_digest_is_process_stable():
    # Pinned values: these must never drift (checkpoint/bench contracts).
    assert faults.key_digest(("a", 1)) == faults.key_digest(("a", 1))
    assert faults.key_digest(("a", 1)) != faults.key_digest(("a", 2))
    assert isinstance(faults.key_digest("k"), int)


def test_transient_executor_schedule():
    m = faults.get_fault("transient_executor")(failures=2)
    for attempt in (0, 1):
        with pytest.raises(faults.TransientExecutorError):
            m.on_dispatch("batch", "k", attempt)
    m.on_dispatch("batch", "k", 2)  # recovered
    m.on_dispatch("solo", "k", 0)  # other lanes untouched
    m.on_dispatch("segment", "k", 0)


def test_worker_crash_schedule():
    m = faults.get_fault("worker_crash")(crashes=1, crash_round=4)
    with pytest.raises(faults.WorkerCrashError):
        m.on_dispatch("batch", "k", 0)
    m.on_dispatch("batch", "k", 1)
    m.on_dispatch("segment", "k", 0)  # before the crash round
    with pytest.raises(faults.WorkerCrashError, match="resume"):
        m.on_dispatch("segment", "k", 4)
    with pytest.raises(faults.WorkerCrashError):
        m.on_dispatch("segment", "k", 6)


def test_compile_failure_is_persistent():
    m = faults.get_fault("compile_failure")()
    for attempt in range(4):
        with pytest.raises(faults.CompileFailureError):
            m.on_dispatch("batch", "k", attempt)


def test_nan_poison_is_deterministic_and_attempt_stable():
    m = faults.get_fault("nan_poison")(seed=11, count=2)
    first = m.poison_cells(8, key="batch-key")
    assert len(first) == 2
    assert all(0 <= i < 8 for i in first)
    # Same (seed, key) -> same cells, across instances (attempt-stability).
    again = faults.get_fault("nan_poison")(seed=11, count=2)
    assert again.poison_cells(8, key="batch-key") == first
    assert m.poison_cells(8, key="other-key") != first or True  # may collide
    assert faults.get_fault("nan_poison")(seed=12, count=2) \
        .poison_cells(8, key="batch-key") != first
    # Clamped to the batch size, never out of range.
    assert faults.get_fault("nan_poison")(count=5).poison_cells(2, "k") == (0, 1)
    assert faults.get_fault("nan_poison")(count=0).poison_cells(4, "k") == ()


def test_chaos_schedule_is_reproducible_per_instance():
    def run(model):
        trace = []
        for n in range(3):
            try:
                model.on_dispatch("batch", f"key{n}", 0)
                trace.append("ok")
            except faults.TransientExecutorError:
                trace.append("transient")
        trace.append(model.poison_cells(4, "key0"))
        trace.append(model.poison_cells(4, "key1"))  # not the poison key
        return trace

    a = run(faults.get_fault("chaos")(seed=3, delay_s=0.0, poison=1))
    b = run(faults.get_fault("chaos")(seed=3, delay_s=0.0, poison=1))
    assert a == b
    assert a[:3] == ["ok", "transient", "ok"]  # dispatch 1 is the transient
    assert len(a[3]) == 1  # first-queried key carries the poison...
    assert a[4] == ()  # ...and only that key
    assert faults.get_fault("chaos").stateful
    assert not faults.get_fault("nan_poison").stateful


# ---------------------------------------------------------------------------
# Recovery primitives driven by the faults.
# ---------------------------------------------------------------------------


def test_backoff_delay_deterministic_and_bounded():
    policy = recovery.RecoveryPolicy(backoff_base_s=0.1, backoff_factor=2.0,
                                     backoff_jitter=0.25, seed=9)
    d1 = recovery.backoff_delay(policy, 1, key="k")
    d2 = recovery.backoff_delay(policy, 2, key="k")
    assert d1 == recovery.backoff_delay(policy, 1, key="k")
    assert 0.075 <= d1 <= 0.125  # base * (1 +- jitter)
    assert 0.15 <= d2 <= 0.25  # base * factor * (1 +- jitter)
    assert recovery.backoff_delay(policy, 1, key="other") != d1


def test_recovery_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        recovery.RecoveryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="backoff_jitter"):
        recovery.RecoveryPolicy(backoff_jitter=1.5)
    with pytest.raises(ValueError, match="breaker_threshold"):
        recovery.RecoveryPolicy(breaker_threshold=0)


def test_circuit_breaker_lifecycle():
    br = recovery.CircuitBreaker(threshold=2, cooldown_s=1e9)
    assert br.allow("k")
    br.record_failure("k")
    assert br.allow("k")  # one failure: still closed
    br.record_failure("k")
    assert not br.allow("k")  # threshold hit: open, cooldown not elapsed
    assert br.state("k") == "open"
    assert br.allow("other")  # per-key isolation
    snap = br.snapshot()
    assert snap["open"] == [repr("k")]
    assert snap["half_open"] == []

    fast = recovery.CircuitBreaker(threshold=1, cooldown_s=0.0)
    fast.record_failure("k")
    assert fast.allow("k")  # cooldown elapsed: half-open probe admitted
    assert fast.state("k") == "half_open"
    assert not fast.allow("k")  # exactly ONE probe
    fast.record_success("k")
    assert fast.state("k") == "closed"
    assert fast.allow("k")


def test_run_with_deadline():
    assert recovery.run_with_deadline(lambda: 42, None, label="x") == 42
    assert recovery.run_with_deadline(lambda: 42, 5.0, label="x") == 42
    with pytest.raises(recovery.JobTimeoutError, match="deadline"):
        recovery.run_with_deadline(
            lambda: __import__("time").sleep(2.0), 0.05, label="slow batch")
    with pytest.raises(KeyError):  # errors relayed verbatim, not wrapped
        recovery.run_with_deadline(
            lambda: {}["missing"], 5.0, label="x")
