"""Replicated serve cluster: leases, heartbeats, takeover, chaos (PR 10).

Pins the cross-process robustness contract on top of the PR-9 serve stack:

* lease acquisition is mutually exclusive under genuinely concurrent
  claimants, and takeover of an expired lease has exactly one winner with
  the epoch bumped (the fencing token);
* an in-process cluster delivers every tenant's stream bit-identical to a
  solo ``Session`` run -- replication changes availability, not results;
* a replica SIGKILLed (in-process: the uncatchable ``ReplicaKilled``)
  mid-checkpoint-segment leaves its lease to expire; a peer steals it and
  resumes from the shared checkpoint directory bit-identically to an
  uninterrupted run;
* delivery is exactly-once under ``net_duplicate`` and converges under
  ``net_drop`` (at-least-once re-send + link-once result records);
* nothing ever hangs under ``net_partition``: the client's bounded wait
  raises the typed ``ClusterUnavailableError``, or a live peer serves;
* replaying one ``(seed, fault model, submission order)`` schedule
  reproduces the identical counters -- chaos is deterministic;
* the result cache (TTL + LRU) and the injectable clock behave exactly;
* one REAL subprocess scenario: ``python -m repro serve --replica-of``
  replicas, a real ``SIGKILL``, and a peer takeover observed end to end.
"""

import json
import os
import pathlib
import signal
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro import api
from repro.core import baselines, faults
from repro.core.simulate import ClusterModel
from repro.serve import (
    CellDivergenceError,
    ClusterClient,
    ClusterReplica,
    ClusterUnavailableError,
    CoalescePolicy,
    ExperimentService,
    LeaseManager,
    ManualClock,
    RecoveryPolicy,
    SpecValidationError,
    TTLCache,
    job_key,
    run_cluster,
)

K, D = 4, 256
REPO = pathlib.Path(__file__).resolve().parents[1]


def _problem_spec(seed=0):
    return api.ProblemSpec("linear_synthetic",
                           {"num_workers": K, "n_per_worker": 48, "d": D,
                            "nnz_per_row": 12, "seed": seed, "lam": 1e-3})


def _spec(name="t", seed=0, num_outer=4, eval_every=2, **kw):
    method = baselines.cocoa_plus(K, H=8)
    return api.ExperimentSpec(
        name=name, problem=_problem_spec(),
        cluster=ClusterModel(num_workers=K, straggler_sigma=5.0,
                             delay_model="constant"),
        methods=(api.MethodEntry(method, num_outer),),
        eval_every=eval_every, seed=seed, **kw)


def _policy(**kw):
    kw.setdefault("batch", "map")
    kw.setdefault("shard", "none")
    kw.setdefault("max_wait_s", 0.0)
    kw.setdefault("max_tenant_depth", 8)
    return CoalescePolicy(**kw)


def _service_kwargs():
    return dict(policy=_policy(),
                recovery=RecoveryPolicy(backoff_base_s=0.001))


def _replicas(cluster_dir, clock, ids, fault_by_id=None, **kw):
    fault_by_id = fault_by_id or {}
    return [ClusterReplica(cluster_dir, rid, clock=clock,
                           fault=fault_by_id.get(rid),
                           service_kwargs=_service_kwargs(), **kw)
            for rid in ids]


def _solo_events(spec):
    entry = spec.methods[0]
    sess = api.Session(spec.problem.build(), entry.config, spec.cluster,
                       num_outer=entry.num_outer, seed=spec.seed,
                       eval_every=spec.eval_every)
    events = list(sess.events())
    return events, sess.result()


def _reference_run(spec, checkpoint_dir):
    """An UNINTERRUPTED run of ``spec`` through a solo service -- the
    bit-identity oracle for checkpointed cluster jobs."""
    svc = ExperimentService(_policy(), checkpoint_dir=checkpoint_dir)
    h = svc.submit("ref", spec)
    svc.drain()
    return list(h.events(timeout=60)), h.result(timeout=60)


# ---------------------------------------------------------------------------
# Lease substrate: mutual exclusion, expiry, takeover, fencing.
# ---------------------------------------------------------------------------


class TestLeases:
    def test_concurrent_claim_has_exactly_one_winner(self, tmp_path):
        n = 8
        managers = [LeaseManager(tmp_path, f"r{i}") for i in range(n)]
        barrier = threading.Barrier(n)
        wins = [None] * n

        def claim(i):
            barrier.wait()
            wins[i] = managers[i].try_acquire("job-x", epoch=0)

        threads = [threading.Thread(target=claim, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        winners = [i for i, w in enumerate(wins) if w is not None]
        assert len(winners) == 1
        lease = managers[0].read_lease("job-x")
        assert lease["owner"] == f"r{winners[0]}"
        assert lease["epoch"] == 0

    def test_concurrent_takeover_has_exactly_one_winner(self, tmp_path):
        clock = ManualClock()
        owner = LeaseManager(tmp_path, "dead", clock=clock, lease_ttl_s=5.0)
        owner.heartbeat()
        assert owner.try_acquire("job-x") is not None
        clock.advance(6.0)  # heartbeat goes stale -> owner presumed dead

        n = 6
        managers = [LeaseManager(tmp_path, f"r{i}", clock=clock,
                                 lease_ttl_s=5.0) for i in range(n)]
        for m in managers:
            m.heartbeat()  # claimants are alive -- only "dead" stays stale
        barrier = threading.Barrier(n)
        wins = [None] * n

        def steal(i):
            barrier.wait()
            wins[i] = managers[i].try_takeover("job-x")

        threads = [threading.Thread(target=steal, args=(i,))
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        winners = [i for i, w in enumerate(wins) if w is not None]
        assert len(winners) == 1
        lease = owner.read_lease("job-x")
        assert lease["owner"] == f"r{winners[0]}"
        assert lease["epoch"] == 1  # the fencing token moved

    def test_epoch_fences_a_superseded_owner(self, tmp_path):
        clock = ManualClock()
        r0 = LeaseManager(tmp_path, "r0", clock=clock, lease_ttl_s=5.0)
        r1 = LeaseManager(tmp_path, "r1", clock=clock, lease_ttl_s=5.0)
        r0.heartbeat()
        r1.heartbeat()
        assert r0.try_acquire("j") is not None
        assert r0.still_owner("j", 0)
        clock.advance(6.0)
        r1.heartbeat()  # r1 stays alive; r0's beat is now stale
        stolen = r1.try_takeover("j")
        assert stolen is not None and stolen["epoch"] == 1
        # the resurrected r0 must discard, not deliver
        assert not r0.still_owner("j", 0)
        assert not r0.release("j", 0)
        assert r1.still_owner("j", 1)
        assert r1.release("j", 1)

    def test_self_owned_lease_never_expires(self, tmp_path):
        clock = ManualClock()
        r0 = LeaseManager(tmp_path, "r0", clock=clock, lease_ttl_s=5.0)
        lease = r0.try_acquire("j")
        clock.advance(100.0)  # r0 never even heartbeat
        assert not r0.expired(lease)
        other = LeaseManager(tmp_path, "r1", clock=clock, lease_ttl_s=5.0)
        assert other.expired(lease)

    def test_takeover_refuses_a_live_owner(self, tmp_path):
        clock = ManualClock()
        r0 = LeaseManager(tmp_path, "r0", clock=clock, lease_ttl_s=5.0)
        r1 = LeaseManager(tmp_path, "r1", clock=clock, lease_ttl_s=5.0)
        r0.heartbeat()
        r0.try_acquire("j")
        assert r1.try_takeover("j") is None
        assert r0.still_owner("j", 0)

    def test_membership_ages_and_retire_withdraws(self, tmp_path):
        clock = ManualClock()
        r0 = LeaseManager(tmp_path, "r0", clock=clock, lease_ttl_s=5.0)
        r1 = LeaseManager(tmp_path, "r1", clock=clock, lease_ttl_s=5.0)
        r0.heartbeat()
        clock.advance(3.0)
        r1.heartbeat()
        m = r0.membership()
        assert m["r0"]["age_s"] == 3.0 and m["r0"]["alive"]
        assert m["r1"]["age_s"] == 0.0 and m["r1"]["alive"]
        clock.advance(3.0)
        m = r0.membership()
        assert not m["r0"]["alive"] and m["r1"]["alive"]
        r1.retire()
        assert "r1" not in r0.membership()


# ---------------------------------------------------------------------------
# Fault-free cluster: delivery is bit-identical to solo sessions.
# ---------------------------------------------------------------------------


class TestClusterDelivery:
    def test_cluster_run_is_bit_identical_to_solo(self, tmp_path):
        clock = ManualClock()
        replicas = _replicas(tmp_path, clock, ["r0", "r1", "r2"])
        client = ClusterClient(tmp_path, clock=clock)
        specs = {"alice": _spec(seed=0), "bob": _spec(seed=1)}
        keys = {t: client.submit(t, s) for t, s in specs.items()}
        summary = run_cluster(replicas, client)
        assert summary["hung_jobs"] == 0 and not summary["dead"]
        for tenant, spec in specs.items():
            events, result = client.try_result(keys[tenant])
            solo_events, solo_result = _solo_events(spec)
            assert events == solo_events
            np.testing.assert_array_equal(result.w, solo_result.w)
            np.testing.assert_array_equal(result.alpha, solo_result.alpha)

    def test_job_key_is_idempotent_and_tenant_scoped(self):
        a, b = _spec(seed=0), _spec(seed=0)
        assert job_key("t", a, None) == job_key("t", b, None)
        assert job_key("t", a, None) != job_key("u", a, None)
        assert job_key("t", a, None) != job_key("t", _spec(seed=1), None)

    def test_resubmitting_identical_work_reuses_the_job(self, tmp_path):
        clock = ManualClock()
        replicas = _replicas(tmp_path, clock, ["r0"])
        client = ClusterClient(tmp_path, clock=clock)
        k1 = client.submit("t", _spec(seed=0))
        k2 = client.submit("t", _spec(seed=0))
        assert k1 == k2
        summary = run_cluster(replicas, client)
        assert summary["hung_jobs"] == 0
        assert replicas[0].counters["completed"] == 1  # ran ONCE
        assert len(list((tmp_path / "results").glob("*.json"))) == 1

    def test_invalid_spec_is_rejected_client_side(self, tmp_path):
        client = ClusterClient(tmp_path, clock=ManualClock())
        with pytest.raises(SpecValidationError):
            client.submit("t", _spec(checkpoint_every=0))

    def test_replica_error_arrives_as_the_original_typed_error(
            self, tmp_path):
        clock = ManualClock()
        replicas = _replicas(
            tmp_path, clock, ["r0"],
            fault_by_id={"r0": faults.get_fault("nan_poison")(seed=3,
                                                              count=1)})
        client = ClusterClient(tmp_path, clock=clock)
        key = client.submit("t", _spec(seed=0))
        summary = run_cluster(replicas, client)
        assert summary["hung_jobs"] == 0
        assert replicas[0].counters["errored"] == 1
        with pytest.raises(CellDivergenceError):
            client.try_result(key)
        assert client.counters["errored"] == 1

    def test_health_reports_cluster_membership_and_leases(self, tmp_path):
        clock = ManualClock()
        replicas = _replicas(tmp_path, clock, ["r0", "r1"])
        client = ClusterClient(tmp_path, clock=clock)
        client.submit("t", _spec(seed=0))
        run_cluster(replicas, client)
        health = replicas[0].service.health()
        assert "breaker_states" in health
        cluster = health["cluster"]
        assert cluster["replica_id"] == "r0"
        assert set(cluster["membership"]) == {"r0", "r1"}
        assert cluster["leases"] == {}  # released after delivery
        assert cluster["transport"]["sent"] > 0


# ---------------------------------------------------------------------------
# Kill + takeover: a peer resumes the checkpointed run bit-identically.
# ---------------------------------------------------------------------------


class TestKillAndTakeover:
    def test_killed_mid_segment_peer_resumes_bit_identically(self, tmp_path):
        cluster_dir = tmp_path / "cluster"
        spec = _spec(seed=0, num_outer=6, checkpoint_every=2)
        ref_events, ref_result = _reference_run(spec, tmp_path / "ref")

        clock = ManualClock()
        kill = faults.get_fault("replica_kill")(replica="r0", at_segment=2)
        replicas = _replicas(cluster_dir, clock, ["r0", "r1"],
                             fault_by_id={"r0": kill}, lease_ttl_s=5.0)
        client = ClusterClient(cluster_dir, clock=clock)
        key = client.submit("t", spec)
        summary = run_cluster(replicas, client, clock=clock, advance_s=1.0)

        assert "r0" in summary["dead"]
        assert "checkpoint segment starting round 2" in summary["dead"]["r0"]
        assert summary["hung_jobs"] == 0
        assert replicas[0].counters["claims"] == 1
        assert replicas[1].counters["takeovers"] == 1

        events, result = client.try_result(key)
        assert events == ref_events
        np.testing.assert_array_equal(result.w, ref_result.w)
        np.testing.assert_array_equal(result.alpha, ref_result.alpha)
        record = json.loads(
            (cluster_dir / "results" / f"{key}.json").read_text())
        assert record["owner"] == "r1" and record["epoch"] == 1

    def test_replica_killed_at_tick_leaves_peers_serving(self, tmp_path):
        clock = ManualClock()
        kill = faults.get_fault("replica_kill")(replica="r0", after_steps=1)
        replicas = _replicas(tmp_path, clock, ["r0", "r1"],
                             fault_by_id={"r0": kill})
        client = ClusterClient(tmp_path, clock=clock)
        keys = [client.submit("t", _spec(seed=i)) for i in range(2)]
        summary = run_cluster(replicas, client, clock=clock, advance_s=1.0)
        assert summary["dead"] == {
            "r0": "replica r0 killed at scheduler tick 1"}
        assert summary["hung_jobs"] == 0
        assert replicas[1].counters["completed"] == 2
        for key in keys:
            assert client.try_result(key) is not None


# ---------------------------------------------------------------------------
# Network faults: exactly-once, drop convergence, partition no-hang.
# ---------------------------------------------------------------------------


class TestNetworkFaults:
    def test_exactly_once_under_duplication(self, tmp_path):
        clock = ManualClock()
        dup = faults.get_fault("net_duplicate")
        replicas = _replicas(
            tmp_path, clock, ["r0"],
            fault_by_id={"r0": dup(seed=6, rate=1.0, kinds="result")})
        client = ClusterClient(
            tmp_path, clock=clock,
            fault=dup(seed=5, rate=1.0, kinds="job"))
        spec = _spec(seed=0)
        key = client.submit("t", spec)
        summary = run_cluster(replicas, client)
        assert summary["hung_jobs"] == 0
        assert client.transport.counters["duplicated"] >= 1
        assert replicas[0].transport.counters["duplicated"] >= 1
        assert replicas[0].transport.counters["deduped_results"] >= 1
        assert replicas[0].counters["completed"] == 1
        assert len(list((tmp_path / "results").glob("*.json"))) == 1
        events, result = client.try_result(key)
        solo_events, solo_result = _solo_events(spec)
        assert events == solo_events
        np.testing.assert_array_equal(result.w, solo_result.w)

    def test_at_least_once_converges_under_drops(self, tmp_path):
        clock = ManualClock()
        drop = faults.get_fault("net_drop")
        replicas = _replicas(
            tmp_path, clock, ["r0"],
            fault_by_id={"r0": drop(seed=4, rate=0.6, kinds="result")})
        client = ClusterClient(
            tmp_path, clock=clock,
            fault=drop(seed=3, rate=0.6, kinds="job"))
        key = client.submit("t", _spec(seed=0))
        summary = run_cluster(replicas, client)
        assert summary["hung_jobs"] == 0
        # drops genuinely happened; fresh fate draws on re-send converged
        assert (client.transport.counters["dropped"] >= 1
                or replicas[0].transport.counters["dropped"] >= 1)
        assert client.try_result(key) is not None

    def test_partitioned_cluster_never_hangs_the_client(self, tmp_path):
        clock = ManualClock()
        part = faults.get_fault("net_partition")(replica="r0", start_tick=0)
        replicas = _replicas(tmp_path, clock, ["r0"],
                             fault_by_id={"r0": part})
        client = ClusterClient(tmp_path, clock=clock)
        key = client.submit("t", _spec(seed=0))
        summary = run_cluster(replicas, client, max_ticks=10)
        assert summary["hung_jobs"] == 1  # nobody served it...
        assert replicas[0].counters["partitioned_ticks"] == 10
        # ...but the client's wait is BOUNDED: typed error, no hang.  The
        # shared ManualClock makes the deadline pass without real sleeping.
        with pytest.raises(ClusterUnavailableError):
            client.result(key, timeout_s=5.0, poll_s=1.0)
        with pytest.raises(ClusterUnavailableError):
            client.events(key, timeout_s=5.0, poll_s=1.0)
        assert client.counters["unavailable"] == 2

    def test_partition_heals_and_the_job_completes(self, tmp_path):
        clock = ManualClock()
        part = faults.get_fault("net_partition")(replica="r0", start_tick=1,
                                                 duration=3)
        replicas = _replicas(tmp_path, clock, ["r0"],
                             fault_by_id={"r0": part})
        client = ClusterClient(tmp_path, clock=clock)
        key = client.submit("t", _spec(seed=0))
        summary = run_cluster(replicas, client)
        assert summary["hung_jobs"] == 0
        assert replicas[0].counters["partitioned_ticks"] == 3
        assert client.try_result(key) is not None

    def test_live_peer_serves_around_a_partitioned_replica(self, tmp_path):
        clock = ManualClock()
        part = faults.get_fault("net_partition")(replica="r0", start_tick=0)
        replicas = _replicas(tmp_path, clock, ["r0", "r1"],
                             fault_by_id={"r0": part})
        client = ClusterClient(tmp_path, clock=clock)
        key = client.submit("t", _spec(seed=0))
        summary = run_cluster(replicas, client)
        assert summary["hung_jobs"] == 0
        assert replicas[1].counters["completed"] == 1
        assert replicas[0].counters["completed"] == 0
        assert client.try_result(key) is not None


# ---------------------------------------------------------------------------
# Determinism: one (seed, fault model, submission order) -> one schedule.
# ---------------------------------------------------------------------------


class TestChaosDeterminism:
    @staticmethod
    def _chaos_run(cluster_dir):
        clock = ManualClock()
        chaos = faults.get_fault("cluster_chaos")(
            seed=11, kill_replica="r0", at_segment=2, drop_rate=0.15)
        replicas = _replicas(cluster_dir, clock, ["r0", "r1", "r2"],
                             fault_by_id={"r0": chaos}, lease_ttl_s=2.5)
        client = ClusterClient(cluster_dir, clock=clock)
        keys = [client.submit("t", _spec(seed=i, num_outer=6,
                                         checkpoint_every=2))
                for i in range(3)]
        summary = run_cluster(replicas, client, clock=clock, advance_s=1.0,
                              max_ticks=100)
        return summary, [client.try_result(k) is not None for k in keys]

    def test_replaying_the_schedule_reproduces_identical_counters(
            self, tmp_path):
        first, done_a = self._chaos_run(tmp_path / "a")
        second, done_b = self._chaos_run(tmp_path / "b")
        assert first["hung_jobs"] == 0 and all(done_a)
        assert "r0" in first["dead"]
        assert sum(r["takeovers"] for r in first["replicas"].values()) == 1
        # the acceptance bar: the ENTIRE summary -- ticks, deaths, client
        # counters, per-replica transport + recovery counters -- replays
        assert first == second
        assert done_a == done_b


# ---------------------------------------------------------------------------
# Result cache: TTL + LRU, and the service-level hit path.
# ---------------------------------------------------------------------------


class TestResultCache:
    def test_lru_eviction_order(self):
        cache = TTLCache(max_entries=2)
        cache.put("a", 1)
        cache.put("b", 2)
        assert cache.get("a") == (True, 1)  # refreshes a
        cache.put("c", 3)                   # evicts b, the LRU entry
        assert cache.get("b") == (False, None)
        assert cache.get("a") == (True, 1)
        assert cache.get("c") == (True, 3)
        assert cache.stats()["evicted_lru"] == 1

    def test_ttl_expiry_on_the_injected_clock(self):
        clock = ManualClock()
        cache = TTLCache(max_entries=8, ttl_s=10.0, clock=clock)
        cache.put("a", 1)
        clock.advance(9.0)
        assert cache.get("a") == (True, 1)
        clock.advance(2.0)
        assert cache.get("a") == (False, None)
        stats = cache.stats()
        assert stats["evicted_ttl"] == 1
        assert stats["hits"] == 1 and stats["misses"] == 1

    def test_zero_entries_disables_the_cache(self):
        cache = TTLCache(max_entries=0)
        cache.put("a", 1)
        assert len(cache) == 0
        assert cache.get("a") == (False, None)

    def test_service_result_cache_short_circuits_identical_work(self):
        svc = ExperimentService(_policy(), result_cache_entries=8)
        spec = _spec(seed=0)
        h1 = svc.submit("alice", spec)
        svc.drain()
        events1 = list(h1.events(timeout=30))
        solo = svc.counters["solo_requests"]
        batches = svc.counters["batches"]
        # same WORK, different tenant: served from the result cache without
        # touching the dispatch path at all
        h2 = svc.submit("bob", _spec(seed=0))
        events2 = list(h2.events(timeout=30))
        assert events2 == events1
        np.testing.assert_array_equal(h2.result(timeout=30).w,
                                      h1.result(timeout=30).w)
        assert svc.counters["result_cache_hits"] == 1
        assert svc.counters["solo_requests"] == solo
        assert svc.counters["batches"] == batches
        assert svc.stats()["result_cache"]["hits"] == 1

    def test_service_backoff_runs_on_the_injected_clock(self):
        # Three attempts with a 10s backoff base would real-sleep ~30s; on
        # the ManualClock the test is instant and the retries still happen.
        clock = ManualClock()
        svc = ExperimentService(
            _policy(),
            recovery=RecoveryPolicy(backoff_base_s=10.0, max_attempts=3),
            fault=faults.get_fault("transient_executor")(seed=0, failures=2),
            clock=clock)
        h = svc.submit("a", _spec(seed=0))
        svc.drain()
        assert h.result(timeout=30) is not None
        assert svc.counters["retries"] == 2
        assert clock.monotonic() > 0.0  # the backoff "slept" on this clock


# ---------------------------------------------------------------------------
# The real thing: subprocess replicas, a real SIGKILL, a real takeover.
# ---------------------------------------------------------------------------


class TestSubprocessCluster:
    def _spawn(self, cluster_dir, replica_id, log, fault=None, params=None):
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(REPO / "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, "-m", "repro", "serve",
               "--replica-of", str(cluster_dir), "--replica-id", replica_id,
               "--lease-ttl", "2.0", "--step-interval", "0.05"]
        if fault is not None:
            cmd += ["--fault-model", fault,
                    "--fault-params", json.dumps(params or {})]
        return subprocess.Popen(cmd, cwd=REPO, env=env,
                                stdout=log, stderr=subprocess.STDOUT)

    def test_sigkilled_replica_is_taken_over_by_a_subprocess_peer(
            self, tmp_path):
        cluster_dir = tmp_path / "cluster"
        cluster_dir.mkdir()
        spec = _spec(seed=0, num_outer=6, checkpoint_every=2)
        ref_events, ref_result = _reference_run(spec, tmp_path / "ref")

        client = ClusterClient(cluster_dir)  # system clock: real processes
        key = client.submit("t", spec)

        r1 = None
        with open(tmp_path / "r0.log", "w") as log0, \
                open(tmp_path / "r1.log", "w") as log1:
            r0 = self._spawn(cluster_dir, "r0", log0, fault="replica_kill",
                             params={"replica": "r0", "at_segment": 2})
            try:
                # r0 claims the job, checkpoints segment [0, 2), and takes a
                # REAL self-SIGKILL at the start of segment 2.
                r0.wait(timeout=300)
                assert r0.returncode == -signal.SIGKILL
                lease = LeaseManager(cluster_dir, "observer").read_lease(key)
                assert lease is not None and lease["owner"] == "r0"

                # The peer finds the stale heartbeat, steals the lease, and
                # resumes from r0's durable checkpoint.
                r1 = self._spawn(cluster_dir, "r1", log1)
                events = client.events(key, timeout_s=300, poll_s=0.2)
                result = client.result(key, timeout_s=10)
            finally:
                for proc in (r0, r1):
                    if proc is not None and proc.poll() is None:
                        proc.kill()
                        proc.wait(timeout=30)

        assert events == ref_events
        np.testing.assert_array_equal(result.w, ref_result.w)
        np.testing.assert_array_equal(result.alpha, ref_result.alpha)
        record = json.loads(
            (cluster_dir / "results" / f"{key}.json").read_text())
        assert record["owner"] == "r1" and record["epoch"] == 1
