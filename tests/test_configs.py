"""The assignment table, verbatim: every architecture's numbers must match."""

import pytest

from repro.configs import (ARCH_IDS, INPUT_SHAPES, get_config, input_specs,
                           shape_supported)

TABLE = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "mamba2-780m": (48, 1536, 1, 1, 0, 50280),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assignment_numbers(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = TABLE[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source  # every config cites its source


def test_family_specifics():
    q30 = get_config("qwen3-moe-30b-a3b")
    assert q30.num_experts == 128 and q30.experts_per_token == 8
    q235 = get_config("qwen3-moe-235b-a22b")
    assert q235.num_experts == 128 and q235.experts_per_token == 8
    jam = get_config("jamba-1.5-large-398b")
    assert jam.num_experts == 16 and jam.experts_per_token == 2
    kinds = [l.kind for l in jam.layout]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    m2 = get_config("mamba2-780m")
    assert m2.ssm_state == 128 and m2.layout[0].kind == "mamba"
    g3 = get_config("gemma3-27b")
    windows = [l.window for l in g3.layout]
    assert windows == [1024] * 5 + [None]  # 5:1 local:global
    hb = get_config("hubert-xlarge")
    assert hb.causal is False and hb.frontend == "audio_stub"
    px = get_config("pixtral-12b")
    assert px.frontend == "vision_stub"


def test_stage_layer_counts():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        total = sum(len(layout) * periods for layout, periods in cfg.stages())
        assert total == cfg.num_layers, arch


def test_skip_table_matches_design():
    """DESIGN §5: exactly 8 skipped (arch, shape) pairs."""
    skips = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            ok, why = shape_supported(cfg, shape)
            if not ok:
                skips.append((arch, shape.name, why))
    names = {(a, s) for a, s, _ in skips}
    assert ("hubert-xlarge", "decode_32k") in names
    assert ("hubert-xlarge", "long_500k") in names
    long_runners = {a for a in ARCH_IDS
                    if (a, "long_500k") not in names}
    assert long_runners == {"mamba2-780m", "jamba-1.5-large-398b",
                            "gemma3-27b"}
    assert len(skips) == 8


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_shapes(arch):
    cfg = get_config(arch)
    for shape in INPUT_SHAPES.values():
        ok, _ = shape_supported(cfg, shape)
        if not ok:
            continue
        specs = input_specs(cfg, shape)
        if shape.kind in ("train", "prefill"):
            batch = specs["batch"]
            lead = next(iter(batch.values())).shape[0]
            assert lead == shape.global_batch
            total_seq = sum(
                v.shape[1] for k, v in batch.items()
                if k in ("tokens", "patch_embeds", "frame_embeds"))
            assert total_seq == shape.seq_len
        else:
            assert specs["token"].shape == (shape.global_batch,)
            assert specs["caches"]  # non-empty cache pytree
