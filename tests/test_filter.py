"""Message filter properties (paper Alg. 2 lines 7-9) -- hypothesis-driven."""

import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import filter as flt


@settings(max_examples=40, deadline=None)
@given(st.integers(8, 400), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_conservation_and_count(d, k_div, seed):
    """sent + residual == dw bitwise; mask count == k (exact variant)."""
    rng = np.random.default_rng(seed)
    dw = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    k = max(1, d // k_div)
    res = flt.topk_mask_exact(dw, k)
    assert bool(jnp.all(res.sent + res.residual == dw))
    assert int(res.mask.sum()) == k
    # every kept magnitude >= every dropped magnitude
    kept_min = float(jnp.min(jnp.where(res.mask, jnp.abs(dw), jnp.inf)))
    drop_max = float(jnp.max(jnp.where(res.mask, -jnp.inf, jnp.abs(dw))))
    assert kept_min >= drop_max - 1e-7


@settings(max_examples=30, deadline=None)
@given(st.integers(8, 300), st.integers(0, 2**31 - 1))
def test_threshold_variant_matches_paper_semantics(d, seed):
    """topk_mask keeps everything >= c_k (ties pass), superset of exact-k."""
    rng = np.random.default_rng(seed)
    dw = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    k = max(1, d // 4)
    res = flt.topk_mask(dw, k)
    assert bool(jnp.all(res.mask == (jnp.abs(dw) >= res.threshold)))
    assert int(res.mask.sum()) >= k


def test_compress_decompress_roundtrip():
    rng = np.random.default_rng(0)
    dw = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    k = 50
    vals, idx = flt.compress(dw, k)
    back = flt.decompress(vals, idx, 1000)
    exact = flt.topk_mask_exact(dw, k)
    np.testing.assert_allclose(np.asarray(back), np.asarray(exact.sent),
                               rtol=0, atol=0)


def test_message_bytes_accounting():
    assert flt.message_bytes(1000) == 8000  # 4B value + 4B index
    assert flt.dense_bytes(47236) == 47236 * 4  # RCV1 full model (Table I)
    assert flt.num_kept(47236, 1000 / 47236) == 1000  # paper's rho*d = 1e3
