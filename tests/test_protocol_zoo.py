"""The protocol zoo: CoCoA-lineage entries (pluggable local solvers),
adaptive-B group sizing, the windowed LAG rule, sigma' defaults, the
protocol x delay smoke grid driven by specs, and the unified
unknown-registry-name error path."""

import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core import baselines, engine, solvers
from repro.core.acpd import MethodConfig
from repro.core.simulate import ClusterModel

K, D = 4, 512


def _spec(methods, *, sigma=1.0, delay="constant", delay_params=None,
          eval_every=2, d=D):
    return api.ExperimentSpec(
        name="zoo-test",
        problem=api.ProblemSpec("rcv1_like",
                                {"K": K, "d": d, "n_per_worker": 96}),
        cluster=api.presets.cluster_model(K, sigma=sigma, delay=delay,
                                          delay_params=delay_params or {}),
        methods=tuple(methods), eval_every=eval_every, seed=0)


# ---------------------------------------------------------------------------
# Registries and sigma' defaults.
# ---------------------------------------------------------------------------


def test_new_protocols_registered():
    names = engine.available_protocols()
    for expected in ("cocoa", "cocoa_plus", "adaptive_b"):
        assert expected in names


def test_solver_registry_contents_and_errors():
    assert solvers.available_solvers() == ("accelerated", "importance", "sdca")
    with pytest.raises(ValueError, match="unknown local solver"):
        solvers.get_solver("newton")


def test_sigma_prime_defaults_per_protocol():
    m = baselines.cocoa_v1(K)  # gamma = 1/K, averaging
    assert m.resolved_sigma_prime(K) == 1.0
    m = baselines.cocoa_plus_solver(K, gamma=1.0)  # adding
    assert m.resolved_sigma_prime(K) == float(K)
    m = baselines.acpd_adaptive(K, D, quantile=0.5)  # targets ~K/2 arrivals
    assert m.resolved_sigma_prime(K) == m.gamma * 2
    m = baselines.acpd_adaptive(K, D, quantile=1.0)
    assert m.resolved_sigma_prime(K) == m.gamma * K


# ---------------------------------------------------------------------------
# CoCoA lineage: pluggable local solvers.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("solver", ["sdca", "importance", "accelerated"])
@pytest.mark.parametrize("builder", [baselines.cocoa_v1,
                                     baselines.cocoa_plus_solver],
                         ids=["cocoa", "cocoa_plus"])
def test_cocoa_lineage_converges_with_every_solver(small_problem, builder,
                                                   solver):
    m = builder(K, H=192, local_solver=solver)
    res = engine.run_method(small_problem, m, ClusterModel(num_workers=K),
                            num_outer=10, eval_every=1, seed=1)
    gaps = [r.gap for r in res.records]
    assert gaps[-1] < gaps[0] / 5, gaps
    assert np.isfinite(res.w).all()


def test_cocoa_plus_sdca_matches_sync_protocol_updates(small_problem):
    """With the default SDCA solver and gamma=1, the cocoa_plus entry solves
    the same subproblems as the pinned 'sync' protocol -- same sigma', same
    key schedule -- so the trajectories must agree to float tolerance (the
    only difference is vmapping through a registry indirection)."""
    sync = baselines.cocoa_plus(K, H=96)  # protocol="sync"
    plug = baselines.cocoa_plus_solver(K, H=96)  # protocol="cocoa_plus"
    cluster = ClusterModel(num_workers=K)
    a = engine.run_method(small_problem, sync, cluster, num_outer=6,
                          eval_every=3, seed=2)
    b = engine.run_method(small_problem, plug, cluster, num_outer=6,
                          eval_every=3, seed=2)
    np.testing.assert_allclose(a.w, b.w, rtol=1e-5, atol=1e-7)
    assert a.records[-1].sim_time == b.records[-1].sim_time


def test_cocoa_rejects_unsafe_gamma(small_problem):
    """Averaging with sigma'=1 is only safe for gamma <= 1/K; the
    MethodConfig default gamma=1.0 used to diverge silently."""
    m = MethodConfig(name="bad", protocol="cocoa")  # default gamma = 1.0
    with pytest.raises(ValueError, match="gamma <= 1/K"):
        api.Session(small_problem, m, ClusterModel(num_workers=K), num_outer=1)
    # An explicit sigma_prime takes responsibility and is allowed through.
    ok = dataclasses.replace(m, sigma_prime=float(K))
    api.Session(small_problem, ok, ClusterModel(num_workers=K), num_outer=1)


def test_unknown_solver_fails_at_session_construction(small_problem):
    m = dataclasses.replace(baselines.cocoa_v1(K), local_solver="newton")
    with pytest.raises(ValueError, match="unknown local solver"):
        api.Session(small_problem, m, ClusterModel(num_workers=K), num_outer=1)


# ---------------------------------------------------------------------------
# Adaptive-B group sizing.
# ---------------------------------------------------------------------------


def test_adaptive_b_excludes_persistent_straggler(small_problem):
    """With one sigma=20 straggler and quantile=0.5, the learned B must drop
    below K (the server stops waiting for the tail) while convergence and
    the T-periodic full barrier are kept."""
    m = baselines.acpd_adaptive(K, D, T=6, rho_d=64, gamma=0.5, H=96,
                                quantile=0.5)
    session = api.Session(small_problem, m,
                          ClusterModel(num_workers=K, straggler_sigma=20.0),
                          num_outer=3, seed=0)
    res = session.run()
    assert session.proto.current_b < K
    assert session.proto.current_b >= 1
    gaps = [r.gap for r in res.records]
    assert gaps[-1] < gaps[0] / 5, gaps
    # Barrier rounds still wait for everyone.
    assert session.proto.arrivals_needed(5) == K


def test_adaptive_b_respects_b_min(small_problem):
    m = baselines.acpd_adaptive(K, D, T=5, rho_d=64, gamma=0.5, H=32,
                                quantile=0.25, b_min=3)
    session = api.Session(small_problem, m,
                          ClusterModel(num_workers=K, straggler_sigma=10.0),
                          num_outer=2, seed=0)
    session.run()
    assert session.proto.current_b >= 3


def test_adaptive_b_capped_under_tied_latencies(small_problem):
    """Homogeneous cluster: every EWMA ties, so the raw quantile count hits
    K -- more aggregation than the default sigma' covers, which used to
    diverge silently.  B_t must stay capped at ceil(q*K) and the run stay
    bounded even with an aggressive gamma."""
    m = dataclasses.replace(
        baselines.acpd_adaptive(K, D, T=5, rho_d=64, H=64, quantile=0.25),
        gamma=1.0)
    session = api.Session(small_problem, m,
                          ClusterModel(num_workers=K, straggler_sigma=1.0),
                          num_outer=3, seed=0)
    res = session.run()
    assert session.proto.current_b == 1  # ceil(0.25 * 4)
    assert all(np.isfinite(r.gap) for r in res.records)
    gaps = [r.gap for r in res.records]
    assert gaps[-1] < gaps[0], gaps  # converging, not exploding


def test_adaptive_b_validates_params(small_problem):
    bad_q = dataclasses.replace(baselines.acpd_adaptive(K, D),
                                adaptive_quantile=1.5)
    with pytest.raises(ValueError, match="adaptive_quantile"):
        api.Session(small_problem, bad_q, ClusterModel(num_workers=K),
                    num_outer=1)
    bad_ewma = dataclasses.replace(baselines.acpd_adaptive(K, D),
                                   adaptive_ewma=0.0)
    with pytest.raises(ValueError, match="adaptive_ewma"):
        api.Session(small_problem, bad_ewma, ClusterModel(num_workers=K),
                    num_outer=1)


# ---------------------------------------------------------------------------
# Windowed LAG.
# ---------------------------------------------------------------------------


def test_lag_window_validation(small_problem):
    m = dataclasses.replace(baselines.acpd_lag(K, D), lag_window=0)
    with pytest.raises(ValueError, match="lag_window"):
        api.Session(small_problem, m, ClusterModel(num_workers=K), num_outer=1)


def test_lag_window_changes_skipping(small_problem):
    """The D-round window holds the laziness reference up longer than the
    single-reply rule, so it must skip at least as many uploads (here:
    strictly fewer bytes up) while still converging."""
    cluster = ClusterModel(num_workers=K)
    runs = {}
    for window in (1, 10):
        m = baselines.acpd_lag(K, D, B=2, T=8, rho_d=64, gamma=0.5, H=192,
                               lag_xi=1.0, lag_window=window)
        runs[window] = engine.run_method(small_problem, m, cluster,
                                         num_outer=6, eval_every=6, seed=2)
    assert runs[10].records[-1].bytes_up < runs[1].records[-1].bytes_up
    for window, res in runs.items():
        gaps = [r.gap for r in res.records]
        assert gaps[-1] < gaps[0] / 2, (window, gaps)


# ---------------------------------------------------------------------------
# Protocol x delay smoke grid, straight from declarative specs.
# ---------------------------------------------------------------------------

_GRID_METHODS = {
    "group": lambda: baselines.acpd(K, 256, B=2, T=4, rho_d=32, gamma=0.5,
                                    H=16),
    "adaptive_b": lambda: baselines.acpd_adaptive(K, 256, T=4, rho_d=32,
                                                  gamma=0.5, H=16),
    "lag": lambda: baselines.acpd_lag(K, 256, B=2, T=4, rho_d=32, gamma=0.5,
                                      H=16),
    "async": lambda: baselines.acpd_async(K, 256, T=4, rho_d=32, gamma=0.5,
                                          H=16),
    "sync": lambda: baselines.cocoa_plus(K, H=16),
    "cocoa": lambda: baselines.cocoa_v1(K, H=16),
    "cocoa_plus": lambda: baselines.cocoa_plus_solver(K, H=16),
}

_GRID_DELAYS = {
    "constant": {},
    "shifted_exponential": {"tail_mean": 1.0},
    "pareto": {"shape": 1.8, "scale": 0.5},
    "markov": {"p_slow": 0.2, "p_recover": 0.3, "slow_factor": 6.0},
    "bandwidth_coupled": {"link_slowdown": 25.0},
}


@pytest.mark.parametrize("delay", sorted(_GRID_DELAYS))
@pytest.mark.parametrize("protocol", sorted(_GRID_METHODS))
def test_protocol_delay_smoke_grid(protocol, delay):
    """Every registry protocol must run against every delay model from a
    JSON-round-tripped spec: finite records, monotone sim clock, positive
    accounting."""
    method = _GRID_METHODS[protocol]()
    assert method.protocol == protocol
    spec = _spec([api.MethodEntry(method, 2)], sigma=4.0, delay=delay,
                 delay_params=_GRID_DELAYS[delay], d=256)
    spec = api.ExperimentSpec.from_json(spec.to_json())  # exercise the wire
    res = api.Experiment(spec).run()[method.name]
    assert res.records, "no eval records"
    times = [r.sim_time for r in res.records]
    assert all(np.isfinite(r.gap) for r in res.records)
    assert times == sorted(times)
    assert times[-1] > 0
    assert res.records[-1].bytes_up > 0


# ---------------------------------------------------------------------------
# Unified unknown-registry-name error path.
# ---------------------------------------------------------------------------


def test_unknown_protocol_and_compressor_same_error_path(small_problem):
    """Both axes must fail at Session construction with that registry's
    listing -- including the sync protocols, which IGNORE the compressor at
    run time and used to let the typo through silently."""
    cluster = ClusterModel(num_workers=K)
    bad_proto = dataclasses.replace(baselines.acpd(K, D), protocol="nope")
    with pytest.raises(ValueError, match="unknown protocol.*available"):
        api.Session(small_problem, bad_proto, cluster, num_outer=1)

    for base in (baselines.acpd(K, D), baselines.cocoa_plus(K)):
        bad_comp = dataclasses.replace(base, compressor="nope")
        with pytest.raises(ValueError, match="unknown compressor.*available"):
            api.Session(small_problem, bad_comp, cluster, num_outer=1)

    bad_delay = ClusterModel(num_workers=K, delay_model="nope")
    with pytest.raises(ValueError, match="unknown delay model.*available"):
        api.Session(small_problem, baselines.acpd(K, D), bad_delay,
                    num_outer=1)
