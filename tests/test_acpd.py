"""ACPD system behaviour: the paper's claims at test scale.

These are the qualitative claims of Sec. V on a synthetic RCV1-like problem:
  1. ACPD converges to the same optimum as the synchronous methods.
  2. Per communication ROUND it tracks CoCoA+ (Fig. 3 cols 1-2).
  3. Per simulated WALL-CLOCK it beats CoCoA+, dramatically so under a
     sigma=10 straggler (Fig. 3 cols 3-4).
  4. On-wire bytes shrink by ~rho vs dense (Table I).
  5. The ablations order as in the paper: full ACPD fastest, B=K (no
     group-wise) and rho=1 (no sparsity) in between, CoCoA+ slowest.
"""

import numpy as np
import pytest

from repro.core import baselines
from repro.core.acpd import run_method
from repro.core.simulate import ClusterModel

K, D = 4, 512


def _run(problem, method, *, sigma=1.0, outer=8, T=10):
    cluster = ClusterModel(num_workers=K, straggler_sigma=sigma)
    n_iter = outer * T if method.protocol == "group" else outer * T
    return run_method(problem, method, cluster,
                      num_outer=outer if method.protocol == "group" else n_iter,
                      eval_every=2, seed=11)


@pytest.fixture(scope="module")
def runs(small_problem):
    methods = {
        "cocoa+": baselines.cocoa_plus(K, H=384),
        "acpd": baselines.acpd(K, D, B=2, T=10, rho_d=32, gamma=0.5, H=384),
        "acpd_bK": baselines.acpd_full_barrier(K, D, T=10, rho_d=32,
                                               gamma=0.5, H=384),
        "acpd_rho1": baselines.acpd_dense(K, B=2, T=10, gamma=0.5, H=384),
    }
    return {name: _run(small_problem, m) for name, m in methods.items()}


def test_all_methods_converge(runs):
    # the sparse-tail slowdown below 1e-4 is expected (paper Fig. 4a)
    for name, res in runs.items():
        assert res.records[-1].gap < 1e-3, (name, res.records[-1].gap)


def test_gap_monotone_trend(runs):
    """Duality gap should broadly decrease (allow small stochastic bumps)."""
    for name, res in runs.items():
        gaps = np.array([r.gap for r in res.records])
        assert gaps[-1] < gaps[0] * 1e-1, name


def test_bandwidth_reduction(runs):
    """ACPD moves far fewer bytes than the dense group-wise ablation."""
    sparse = runs["acpd"].records[-1].bytes_up
    dense = runs["acpd_rho1"].records[-1].bytes_up
    assert sparse < dense / 5


def test_acpd_faster_than_cocoa_plus_with_straggler(small_problem):
    """Paper's headline: up to ~4x faster under stragglers (sigma=10)."""
    target = 1e-3
    acpd = run_method(small_problem,
                      baselines.acpd(K, D, B=2, T=10, rho_d=64, gamma=0.5, H=384),
                      ClusterModel(num_workers=K, straggler_sigma=10.0),
                      num_outer=8, eval_every=2, seed=3)
    cocoa = run_method(small_problem, baselines.cocoa_plus(K, H=384),
                       ClusterModel(num_workers=K, straggler_sigma=10.0),
                       num_outer=80, eval_every=2, seed=3)
    t_acpd = acpd.time_to_gap(target)
    t_cocoa = cocoa.time_to_gap(target)
    assert t_acpd is not None and t_cocoa is not None
    assert t_acpd < t_cocoa, (t_acpd, t_cocoa)
    # Analytic ceiling at this scale: CoCoA+ waits sigma*c every round; ACPD
    # (B=2of4, T=10) only on sync rounds -> ~5x/round, ~2.4x more rounds ->
    # net ~2x. The paper's 4x additionally needs comm-dominant d (Fig. 5).
    assert t_cocoa / t_acpd > 1.5


def test_exact_dual_feedback_maintains_primal_dual_relation():
    """Alg. 2 lines 10-12 (theory variant): with the dual put-back, the
    server model equals (1/lam n) A alpha at every evaluation -- the invariant
    Lemma 1's analysis relies on. Needs n_k >= d so the unsent mass lies in
    col(A_[k])."""
    import dataclasses as _dc

    import jax.numpy as jnp

    from repro.core.objectives import primal_from_dual
    from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

    prob = make_linear_problem(
        LinearDatasetSpec(num_workers=2, n_per_worker=96, d=64,
                          nnz_per_row=16, seed=33), lam=1e-2)
    m = baselines.acpd(2, 64, B=1, T=5, rho_d=8, gamma=0.5, H=128)
    m = _dc.replace(m, exact_dual_feedback=True)
    res = run_method(prob, m, ClusterModel(num_workers=2), num_outer=4,
                     eval_every=1, seed=0)
    # reconstruct w(alpha) from the server-visible duals (worker-canonical
    # alpha leads the server by the in-flight messages, so use alpha_applied)
    w_alpha = primal_from_dual(jnp.asarray(res.alpha_applied), prob.X, prob.lam)
    err = float(jnp.max(jnp.abs(w_alpha - jnp.asarray(res.w))))
    assert err < 5e-4, err
    # and the practical variant must violate it (that's the simplification)
    res2 = run_method(prob, baselines.acpd(2, 64, B=1, T=5, rho_d=8,
                                           gamma=0.5, H=128),
                      ClusterModel(num_workers=2), num_outer=4, eval_every=1,
                      seed=0)
    w2 = primal_from_dual(jnp.asarray(res2.alpha_applied), prob.X, prob.lam)
    err2 = float(jnp.max(jnp.abs(w2 - jnp.asarray(res2.w))))
    assert err2 > 10 * max(err, 1e-6), (err, err2)


def test_staleness_bounded_by_T(small_problem):
    """Every worker is collected at the T-boundary: after any full sync, all
    workers' applied duals are fresh -- proxy: gap_server ~ gap."""
    res = run_method(small_problem,
                     baselines.acpd(K, D, B=2, T=5, rho_d=64, gamma=0.5, H=256),
                     ClusterModel(num_workers=K, straggler_sigma=5.0),
                     num_outer=6, eval_every=1, seed=5)
    # server-model gap must track the dual-certified gap within a constant
    g = np.array([r.gap for r in res.records[5:]])
    gs = np.array([r.gap_server for r in res.records[5:]])
    assert np.all(gs < 10 * g + 1e-4)


def test_round_for_round_parity_with_cocoa_plus(small_problem):
    """Fig. 3 cols 1-2: sigma=1, ACPD needs at most ~2x the rounds of CoCoA+
    to reach a mid accuracy (group-wise updates carry B/K of the info)."""
    target = 1e-3
    acpd = run_method(small_problem,
                      baselines.acpd(K, D, B=2, T=10, rho_d=64, gamma=0.5, H=384),
                      ClusterModel(num_workers=K), num_outer=10, eval_every=1,
                      seed=7)
    cocoa = run_method(small_problem, baselines.cocoa_plus(K, H=384),
                       ClusterModel(num_workers=K), num_outer=100,
                       eval_every=1, seed=7)
    r_acpd = acpd.rounds_to_gap(target)
    r_cocoa = cocoa.rounds_to_gap(target)
    assert r_acpd is not None and r_cocoa is not None
    # each ACPD round applies B=K/2 workers' updates -> allow 3x rounds
    assert r_acpd <= 3 * r_cocoa
