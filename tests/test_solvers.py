"""Alternative local solvers: work-normalized comparison vs plain SDCA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import objectives as obj
from repro.core.sdca import solve_subproblem
from repro.core.solvers import (solve_subproblem_accelerated,
                                solve_subproblem_importance)


def _problem(seed=0, n_k=96, d=192, hetero=True):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((n_k, d)).astype(np.float32) / np.sqrt(d)
    if hetero:  # importance sampling only matters with non-uniform norms
        X *= rng.uniform(0.1, 3.0, (n_k, 1)).astype(np.float32)
    y = np.sign(rng.standard_normal(n_k)).astype(np.float32)
    return (jnp.asarray(X), jnp.asarray(y),
            jnp.asarray(np.sum(X * X, 1)))


def _dual_gain(solver, X, y, norms, H, seed=0, **kw):
    lam, n, sp = 1e-2, X.shape[0], 1.0
    res = solver(jnp.zeros(X.shape[1]), jnp.zeros(X.shape[0]), X, y, norms,
                 lam, n, sp, jax.random.key(seed), loss="ridge",
                 num_steps=H, **kw)
    # local subproblem value gained (constants cancel at dalpha=0)
    v = res.v
    a = res.delta_alpha
    return (float(jnp.sum(obj.neg_conj("ridge", a, y))) / n
            - 0.5 * lam * sp * float(v @ v))


def test_importance_sampling_is_valid_ascent():
    """Empirical note (recorded, not asserted as superiority): on this ridge
    instance the smoothness-proportional distribution UNDERPERFORMS uniform
    by ~30% in early dual gain -- the Zhang-Xiao bound optimizes the worst
    case, and exact coordinate maximization already divides each step's gain
    by (1 + q_i), cancelling the intended bias. We assert only the
    correctness properties: positive monotone gain within a factor of
    uniform's (same optimum, slower constant)."""
    X, y, norms = _problem(hetero=True)
    uni = np.mean([_dual_gain(solve_subproblem, X, y, norms, 64, s)
                   for s in range(6)])
    imp = np.mean([_dual_gain(solve_subproblem_importance, X, y, norms, 64, s)
                   for s in range(6)])
    assert imp > 0
    assert imp >= 0.5 * uni  # same-order progress, documented slowdown


def test_accelerated_converges_and_is_consistent():
    X, y, norms = _problem()
    lam, n, sp = 1e-2, X.shape[0], 1.0
    res = solve_subproblem_accelerated(
        jnp.zeros(X.shape[1]), jnp.zeros(X.shape[0]), X, y, norms, lam, n,
        sp, jax.random.key(1), loss="ridge", num_steps=400)
    # v must remain consistent with dalpha (the ACPD invariant, Alg.2 l.6)
    v_expect = X.T @ res.delta_alpha / (lam * n)
    np.testing.assert_allclose(np.asarray(res.v), np.asarray(v_expect),
                               rtol=1e-4, atol=1e-5)
    gain = _dual_gain(solve_subproblem_accelerated, X, y, norms, 400, 2)
    plain = _dual_gain(solve_subproblem, X, y, norms, 400, 2)
    assert gain > 0 and gain >= 0.8 * plain  # same work, comparable progress


@pytest.mark.parametrize("solver", [solve_subproblem_importance])
def test_alternative_solvers_are_ascent(solver):
    X, y, norms = _problem(seed=3)
    gains = [_dual_gain(solver, X, y, norms, H, 0) for H in (16, 64, 256)]
    assert gains[0] <= gains[1] <= gains[2] + 1e-6
