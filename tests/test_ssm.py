"""Mamba2/SSD: chunked prefill == sequential recurrence; chunk invariance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.param import tree_materialize


def _cfg(chunk=8, state=16, d_model=64):
    return ModelConfig(arch_id="t", family="ssm", num_layers=1, d_model=d_model,
                       num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=128,
                       ssm_state=state, ssm_expand=2, ssm_head_dim=32,
                       ssm_chunk=chunk, param_dtype="float32",
                       compute_dtype="float32")


@pytest.mark.parametrize("S", [8, 21, 64])
def test_prefill_equals_stepwise(S):
    cfg = _cfg()
    params = tree_materialize(ssm.ssm_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, S, cfg.d_model)) * 0.5
    y_full = ssm.ssm_forward(params, x, cfg)
    cache = ssm.ssm_init_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(S):
        yt, cache = ssm.ssm_decode_step(params, x[:, t:t + 1], cache, cfg)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=2e-5)


def test_chunk_size_invariance():
    x = jax.random.normal(jax.random.key(2), (1, 48, 64)) * 0.5
    outs = []
    for chunk in (4, 12, 48):
        cfg = _cfg(chunk=chunk)
        params = tree_materialize(ssm.ssm_spec(cfg), jax.random.key(0))
        outs.append(ssm.ssm_forward(params, x, cfg))
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=2e-5)


def test_prefill_cache_continues_decode():
    """prefill(return_cache) then decode == full forward, token by token."""
    cfg = _cfg()
    params = tree_materialize(ssm.ssm_spec(cfg), jax.random.key(0))
    S, extra = 19, 5
    x = jax.random.normal(jax.random.key(3), (2, S + extra, cfg.d_model)) * 0.5
    y_all = ssm.ssm_forward(params, x, cfg)
    y_pre, cache = ssm.ssm_forward(params, x[:, :S], cfg, return_cache=True)
    np.testing.assert_allclose(np.asarray(y_all[:, :S]), np.asarray(y_pre),
                               rtol=1e-4, atol=2e-5)
    for t in range(S, S + extra):
        yt, cache = ssm.ssm_decode_step(params, x[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(np.asarray(y_all[:, t:t + 1]),
                                   np.asarray(yt), rtol=1e-4, atol=5e-5)


def test_grads_finite():
    cfg = _cfg()
    params = tree_materialize(ssm.ssm_spec(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(4), (2, 32, cfg.d_model))

    def loss(p):
        return jnp.sum(jnp.square(ssm.ssm_forward(p, x, cfg)))

    grads = jax.grad(loss)(params)
    assert all(np.isfinite(np.asarray(g)).all() for g in jax.tree.leaves(grads))
