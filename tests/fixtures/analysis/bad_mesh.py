"""Seeded violations for the `mesh-via-make-mesh` rule."""

import jax
from jax.experimental import mesh_utils


def build_mesh():
    devices = mesh_utils.create_device_mesh((1,))  # VIOLATION
    return jax.sharding.Mesh(devices, ("cells",))  # VIOLATION
