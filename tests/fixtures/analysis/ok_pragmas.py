"""The negative fixture: every violation class, each pragma-suppressed.

Must produce ZERO findings -- asserts the pragma grammar end to end
(`host-ok` / `x64-ok` aliases, `ignore[rule]`, def-scoped suppression).
"""

import time

import jax
import jax.numpy as jnp


def step(carry, _):
    t = time.time()  # analysis: host-ok
    return carry + t, None


def step2(carry, _):  # analysis: ignore[traced-host-sync]
    # Def-scoped pragma: suppresses every line in this function.
    scale = float(carry)
    return carry * scale, None


def run(x):
    y, _ = jax.lax.scan(step, x, None, length=2)
    z, _ = jax.lax.scan(step2, y, None, length=2)
    return z


def timings(n):  # analysis: x64-ok
    return jnp.zeros((n,), jnp.float64)


@jax.jit  # analysis: ignore[jit-donation]
def update(state, grad):
    return state - grad


def flatten_params(tree):
    return jax.tree.flatten_with_path(tree)  # analysis: ignore
