"""Seeded violations for the `registry-hooks` rule.

Linted as source only (never imported), so nothing here reaches the real
registries.
"""

from repro.core.compress import Compressor, register_compressor
from repro.core.engine import Protocol, register_protocol
from repro.core.solvers import register_solver


@register_protocol("fixture_bad_proto")  # VIOLATION (missing hooks)
class IncompleteProtocol(Protocol):
    def num_rounds(self, R):
        return R


@register_compressor("fixture_bad_comp")  # VIOLATION (missing hooks)
class IncompleteCompressor(Compressor):
    def compress(self, dw):
        return dw, dw


def not_a_solver(w_eff, alpha):
    return alpha


register_solver("fixture_bad_solver")(not_a_solver)  # VIOLATION
