"""Seeded violations for the `jit-donation` rule."""

from functools import partial

import jax


@jax.jit  # VIOLATION
def update(state, grad):
    return {k: state[k] - grad[k] for k in state}


@partial(jax.jit, static_argnames=("lr",))  # VIOLATION
def sgd_step(opt_state, grad, *, lr):
    return opt_state - lr * grad


def make_step(cfg):
    def body(carry, batch):
        return carry, batch

    return jax.jit(body)  # VIOLATION (carry not donated)


@partial(jax.jit, donate_argnums=(0,))  # ok: donates its carry
def donated(state, grad):
    return state - grad


@jax.jit  # ok: no carry-style parameters
def evaluate(params, batch):
    return params, batch
