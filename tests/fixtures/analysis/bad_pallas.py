"""Seeded violations for the `pallas-scalar-index` rule."""

from jax.experimental import pallas as pl


def kernel(x_ref, o_ref):
    k = pl.program_id(0)
    o_ref[k] = x_ref[k] + 1.0  # VIOLATION  # VIOLATION (both subscripts)
    row = pl.load(x_ref, (k, slice(None)))  # VIOLATION
    pl.store(o_ref, (pl.ds(k, 1),), row[None])  # ok: pl.ds
    first = x_ref[0]  # ok: constant index
    return first
