"""Seeded violations for the `f64-without-x64` rule."""

import jax.numpy as jnp


def timings(n):
    return jnp.zeros((n,), jnp.float64)  # VIOLATION


def guarded(n):
    from jax.experimental import enable_x64

    with enable_x64():
        return jnp.zeros((n,), jnp.float64)  # ok: enable_x64 in scope
