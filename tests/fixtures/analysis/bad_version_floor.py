"""Seeded violations for the `version-floor` rule (JAX floor is 0.4.37)."""

import jax


def flatten_params(tree):
    leaves, treedef = jax.tree.flatten_with_path(tree)  # VIOLATION
    return leaves, treedef


def explicit_axis():
    return jax.sharding.AxisType  # VIOLATION
