"""Seeded violations for the ``typed-errors`` rule.

The path contains ``serve`` on purpose: the rule only patrols the serve
layer, where a swallowed broad except becomes a hung stream or an untyped
500 (the PR-9 failure contract).  Linted as source, never imported.
"""


class TypedError(RuntimeError):
    pass


def swallowed_batch(run, reqs):
    try:
        return run(reqs)
    except Exception as e:  # VIOLATION
        return {"error": repr(e)}


def swallowed_base(run):
    try:
        return run()
    except BaseException:  # VIOLATION
        return None


def swallowed_tuple(run):
    try:
        return run()
    except (ValueError, Exception) as e:  # VIOLATION
        return repr(e)


def reraises_typed(run):
    # Fine: the handler converts to a typed error.
    try:
        return run()
    except Exception as e:
        raise TypedError(f"dispatch failed: {e}") from e


def narrow_is_fine(run):
    # Fine: narrow excepts are not this rule's business.
    try:
        return run()
    except ValueError:
        return None


def marked_terminal(handle, run):
    # Fine: explicitly marked -- the error terminates here by design.
    try:
        return run()
    except Exception as e:  # analysis: fail-fast-ok (delivered to the tenant handle)
        handle.fail(e)
        return None
