"""Seeded violations for the `traced-host-sync` rule.

``step`` is traced (passed to ``lax.scan``); ``host_report`` is plain host
code and must NOT be flagged even though it uses the same calls.
"""

import random
import time

import jax
import numpy as np


def step(carry, _):
    t = time.time()  # VIOLATION
    jitter = random.random()  # VIOLATION
    host = np.asarray(carry)  # VIOLATION
    scale = float(carry)  # VIOLATION
    return carry + t + jitter + host.sum() + scale, None


def helper(x):
    # Reachable from `step`? No -- but reachable from `run` via `step` only.
    return x.item()  # VIOLATION (called from the traced `step` chain below)


def step2(carry, _):
    return helper(carry), None


def run(x):
    y, _ = jax.lax.scan(step, x, None, length=3)
    z, _ = jax.lax.scan(step2, y, None, length=3)
    return z


def host_report(result):
    # Host-side by design: unreachable from any traced entry point.
    print(f"{time.time()}: {float(result):.3f}", np.asarray(result))
