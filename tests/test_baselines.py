"""Synchronous baselines: CoCoA / CoCoA+ / DisDCA."""

import numpy as np

from repro.core import baselines
from repro.core.acpd import run_method
from repro.core.simulate import ClusterModel

K = 4


def test_cocoa_family_converges(small_problem):
    cluster = ClusterModel(num_workers=K)
    for preset in (baselines.cocoa, baselines.cocoa_plus, baselines.disdca):
        res = run_method(small_problem, preset(K, H=384), cluster,
                         num_outer=60, eval_every=10, seed=1)
        assert res.records[-1].gap < 1e-3, preset.__name__


def test_disdca_equals_cocoa_plus(small_problem):
    """Ma et al. 2015: DisDCA (practical) == CoCoA+ under our parameterization;
    identical configs must produce bit-identical trajectories."""
    cluster = ClusterModel(num_workers=K)
    r1 = run_method(small_problem, baselines.cocoa_plus(K, H=256), cluster,
                    num_outer=20, eval_every=5, seed=9)
    r2 = run_method(small_problem, baselines.disdca(K, H=256), cluster,
                    num_outer=20, eval_every=5, seed=9)
    np.testing.assert_allclose(r1.w, r2.w, rtol=0, atol=0)


def test_adding_beats_averaging_per_round(small_problem):
    """CoCoA+ (adding, sigma'=K) should reach a target gap in no more rounds
    than CoCoA (averaging) -- the core claim of Ma et al. reproduced here
    because ACPD inherits the adding aggregation."""
    cluster = ClusterModel(num_workers=K)
    plus = run_method(small_problem, baselines.cocoa_plus(K, H=256), cluster,
                      num_outer=60, eval_every=1, seed=2)
    avg = run_method(small_problem, baselines.cocoa(K, H=256), cluster,
                     num_outer=60, eval_every=1, seed=2)
    target = 1e-3
    r_plus = plus.rounds_to_gap(target)
    r_avg = avg.rounds_to_gap(target)
    assert r_plus is not None
    assert r_avg is None or r_plus <= r_avg
