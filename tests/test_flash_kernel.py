"""Pallas flash-attention forward kernel vs the jnp flash oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_fwd_pallas
from repro.models.flash import FlashSpec, flash_attention


@pytest.mark.parametrize(
    "B,S,KV,G,hd,causal,blk",
    [(2, 64, 2, 2, 16, True, 16), (1, 100, 1, 3, 32, True, 32),  # ragged pad
     (2, 48, 2, 1, 16, False, 16),  # encoder
     (1, 128, 4, 2, 64, True, 64), (1, 96, 2, 2, 16, True, 32)],
)
def test_pallas_flash_matches_jnp(B, S, KV, G, hd, causal, blk):
    rng = np.random.default_rng(S)
    q = jnp.asarray(rng.standard_normal((B, S, KV, G, hd)).astype(np.float32)) * 0.4
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32)) * 0.4
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32))
    out_k = flash_attention_fwd_pallas(q, k, v, causal=causal, block_q=blk,
                                       block_k=blk)
    out_r = flash_attention(q * (hd**-0.5), k, v,
                            FlashSpec(causal, None, blk, blk, None))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=2e-5)


def test_pallas_flash_bf16():
    rng = np.random.default_rng(7)
    B, S, KV, G, hd = 1, 64, 2, 2, 32
    q = (jnp.asarray(rng.standard_normal((B, S, KV, G, hd)).astype(np.float32))
         * 0.4).astype(jnp.bfloat16)
    k = (jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32))
         * 0.4).astype(jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32)
                    ).astype(jnp.bfloat16)
    out_k = flash_attention_fwd_pallas(q, k, v, block_q=32, block_k=32)
    out_r = flash_attention((q.astype(jnp.float32) * hd**-0.5).astype(jnp.bfloat16),
                            k, v, FlashSpec(True, None, 32, 32, None))
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=3e-2)
