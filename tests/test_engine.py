"""Engine regression: the pluggable protocol engine (core/engine.py) must
reproduce the reference implementation (core/acpd.py loops) bit-for-bit for
the seed's ``group`` and ``sync`` protocols, and its new protocols
(``async``, ``lag``) must behave as designed."""

import dataclasses

import numpy as np
import pytest

from repro.core import baselines, engine
from repro.core.acpd import run_method, run_method_reference
from repro.core.simulate import ClusterModel

K, D = 4, 512


def _assert_records_identical(got, want):
    assert len(got.records) == len(want.records)
    for rg, rw in zip(got.records, want.records):
        for f in dataclasses.fields(rg):
            a, b = getattr(rg, f.name), getattr(rw, f.name)
            assert a == b, (f.name, a, b, rg.iteration)


def _assert_runs_identical(got, want):
    _assert_records_identical(got, want)
    np.testing.assert_array_equal(got.w, want.w)
    np.testing.assert_array_equal(got.alpha, want.alpha)
    if want.alpha_applied is None:
        assert got.alpha_applied is None
    else:
        np.testing.assert_array_equal(got.alpha_applied, want.alpha_applied)


@pytest.mark.parametrize("method_fn,kwargs,outer", [
    (baselines.acpd, dict(B=2, T=6, rho_d=32, gamma=0.5, H=96), 3),
    (baselines.acpd_dense, dict(B=2, T=6, gamma=0.5, H=96), 3),
    (baselines.acpd_full_barrier, dict(T=6, rho_d=32, gamma=0.5, H=96), 3),
], ids=["sparse", "dense", "full_barrier"])
def test_group_engine_bit_for_bit(small_problem, method_fn, kwargs, outer):
    if method_fn is baselines.acpd_dense:
        m = method_fn(K, **kwargs)
    else:
        m = method_fn(K, D, **kwargs)
    cluster = ClusterModel(num_workers=K, straggler_sigma=3.0)
    ref = run_method_reference(small_problem, m, cluster, num_outer=outer,
                               eval_every=1, seed=13)
    got = engine.run_method(small_problem, m, cluster, num_outer=outer,
                            eval_every=1, seed=13)
    _assert_runs_identical(got, ref)


def test_group_engine_bit_for_bit_with_jitter(small_problem):
    """Jittered straggler clock: the host-rng draw order must match too."""
    m = baselines.acpd(K, D, B=2, T=5, rho_d=64, gamma=0.5, H=64)
    cluster = ClusterModel(num_workers=K, straggler_sigma=2.0, jitter=0.3)
    ref = run_method_reference(small_problem, m, cluster, num_outer=2,
                               eval_every=2, seed=5)
    got = engine.run_method(small_problem, m, cluster, num_outer=2,
                            eval_every=2, seed=5)
    _assert_runs_identical(got, ref)


def test_sync_engine_bit_for_bit(small_problem):
    m = baselines.cocoa_plus(K, H=96)
    cluster = ClusterModel(num_workers=K, straggler_sigma=3.0)
    ref = run_method_reference(small_problem, m, cluster, num_outer=12,
                               eval_every=3, seed=13)
    got = engine.run_method(small_problem, m, cluster, num_outer=12,
                            eval_every=3, seed=13)
    _assert_runs_identical(got, ref)


def test_run_method_dispatches_to_engine(small_problem):
    """The public entry point and the engine produce the same stream."""
    m = baselines.acpd(K, D, B=2, T=5, rho_d=64, gamma=0.5, H=64)
    cluster = ClusterModel(num_workers=K)
    a = run_method(small_problem, m, cluster, num_outer=2, eval_every=2, seed=3)
    b = engine.run_method(small_problem, m, cluster, num_outer=2, eval_every=2,
                          seed=3)
    _assert_runs_identical(a, b)


def test_registry_contents_and_errors():
    names = engine.available_protocols()
    for expected in ("group", "sync", "async", "lag"):
        assert expected in names
    with pytest.raises(ValueError, match="unknown protocol"):
        engine.get_protocol("nope")


def test_async_rejects_group_sized_B(small_problem):
    """B is a public knob; 'async' must refuse B != 1 instead of silently
    ignoring it."""
    m = dataclasses.replace(baselines.acpd_async(K, D), B=4)
    with pytest.raises(ValueError, match="B=1"):
        run_method(small_problem, m, ClusterModel(num_workers=K),
                   num_outer=1, eval_every=1, seed=0)


def test_async_protocol_converges(small_problem):
    """B=1 per-arrival apply: steady progress despite unbounded staleness.

    Each round applies ONE worker (vs B for the group protocol), so the
    per-round bar is proportionally lower: a 20x gap reduction over 80
    single-arrival rounds, no divergence.
    """
    m = baselines.acpd_async(K, D, T=10, rho_d=64, gamma=0.5, H=256)
    res = run_method(small_problem, m, ClusterModel(num_workers=K,
                                                    straggler_sigma=5.0),
                     num_outer=8, eval_every=4, seed=2)
    gaps = [r.gap for r in res.records]
    assert gaps[-1] < 1e-2, gaps[-1]
    assert gaps[-1] < gaps[0] / 20, (gaps[0], gaps[-1])
    # every round waits for exactly one arrival -> one record per arrival
    assert res.records[-1].iteration == 8 * 10


@pytest.mark.parametrize("window,gap_tol", [(1, 1e-3), (10, 1e-2)],
                         ids=["window1", "window10"])
def test_lag_protocol_converges_and_saves_upload_bytes(small_problem, window,
                                                       gap_tol):
    """Lazy uploads must cut bytes_up vs the plain group protocol without
    giving up convergence (mass is preserved by the residual).

    ``lag_window=1`` is the legacy single-reply test with its original
    thresholds; the paper-faithful D=10 window skips more aggressively
    (early large replies hold the laziness reference up), buying more byte
    savings at a looser same-budget gap.
    """
    cluster = ClusterModel(num_workers=K)
    group = baselines.acpd(K, D, B=2, T=10, rho_d=64, gamma=0.5, H=256)
    lag = baselines.acpd_lag(K, D, B=2, T=10, rho_d=64, gamma=0.5, H=256,
                             lag_xi=1.0, lag_window=window)
    res_g = run_method(small_problem, group, cluster, num_outer=8,
                       eval_every=4, seed=2)
    res_l = run_method(small_problem, lag, cluster, num_outer=8,
                       eval_every=4, seed=2)
    assert res_l.records[-1].gap < gap_tol, res_l.records[-1].gap
    # Strictly fewer upload bytes == heartbeats actually happened (both runs
    # launch the same number of worker rounds; a full upload costs 512 bytes
    # here, a heartbeat 8).
    assert res_l.records[-1].bytes_up < res_g.records[-1].bytes_up, (
        res_l.records[-1].bytes_up, res_g.records[-1].bytes_up)


def test_exact_dual_feedback_stays_on_reference_path():
    """The impractical theory variant cannot be fused; run_method must route
    it to the reference loop (and still produce the Lemma-1 invariant)."""
    m = dataclasses.replace(
        baselines.acpd(2, 64, B=1, T=5, rho_d=8, gamma=0.5, H=64),
        exact_dual_feedback=True)
    from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

    prob = make_linear_problem(
        LinearDatasetSpec(num_workers=2, n_per_worker=96, d=64,
                          nnz_per_row=16, seed=33), lam=1e-2)
    res = run_method(prob, m, ClusterModel(num_workers=2), num_outer=2,
                     eval_every=1, seed=0)
    ref = run_method_reference(prob, m, ClusterModel(num_workers=2),
                               num_outer=2, eval_every=1, seed=0)
    _assert_runs_identical(res, ref)
