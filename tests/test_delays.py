"""Delay-model registry: distribution-shape sanity (seeded), legacy
equivalence of the default ``constant`` model, byte-coupled billing
agreement with the compressor formula, and spec threading."""

import dataclasses

import numpy as np
import pytest

from repro.core import compress as compress_lib
from repro.core import delays
from repro.core.simulate import ClusterModel

K = 4


def _cluster(**kw):
    return ClusterModel(num_workers=K, **kw)


def _samples(model, n, *, k=1, H=100, seed=0):
    rng = np.random.default_rng(seed)
    return np.asarray([model.compute_time(k, H, rng) for _ in range(n)])


# ---------------------------------------------------------------------------
# Registry mechanics.
# ---------------------------------------------------------------------------


def test_registry_contents_and_errors():
    names = delays.available_delays()
    for expected in ("constant", "shifted_exponential", "pareto", "markov",
                     "bandwidth_coupled"):
        assert expected in names
    with pytest.raises(ValueError, match="unknown delay model"):
        delays.get_delay("nope")
    with pytest.raises(ValueError, match="unknown delay model"):
        _cluster(delay_model="nope").make_delay()


def test_bad_params_fail_at_construction():
    with pytest.raises(TypeError):
        _cluster(delay_model="pareto",
                 delay_params={"not_a_param": 1.0}).make_delay()
    with pytest.raises(ValueError, match="shape"):
        _cluster(delay_model="pareto", delay_params={"shape": -1}).make_delay()
    with pytest.raises(ValueError, match="p_slow"):
        _cluster(delay_model="markov", delay_params={"p_slow": 2}).make_delay()
    with pytest.raises(ValueError, match="slow_factor"):
        _cluster(delay_model="markov",
                 delay_params={"slow_factor": -8.0}).make_delay()


def test_delay_params_normalize_and_hash():
    a = _cluster(delay_model="pareto", delay_params={"shape": 2.0, "scale": 0.5})
    b = _cluster(delay_model="pareto",
                 delay_params=(("scale", 0.5), ("shape", 2.0)))
    assert a == b
    assert hash(a) == hash(b)  # stays usable as a dict key / static arg


# ---------------------------------------------------------------------------
# The constant model IS the legacy ClusterModel behavior.
# ---------------------------------------------------------------------------


def test_constant_matches_legacy_formula():
    c = _cluster(straggler_sigma=3.0, unit_time=2e-5)
    rng = np.random.default_rng(0)
    assert c.compute_time(0, 100, rng) == 100 * 2e-5 * 3.0  # straggler
    assert c.compute_time(1, 100, rng) == 100 * 2e-5  # normal worker
    assert c.p2p_time(1000) == c.latency + 1000 / c.bandwidth


def test_constant_jitter_draw_order_matches_legacy():
    """With jitter, the model must consume exactly one lognormal per call
    (the bit-for-bit engine pins depend on the host-RNG draw order)."""
    c = _cluster(jitter=0.4)
    got = _samples(c.make_delay(), 5, seed=9)
    rng = np.random.default_rng(9)
    want = np.asarray(
        [100 * c.unit_time * float(rng.lognormal(0.0, 0.4)) for _ in range(5)])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Distribution shapes (seeded quantile checks).
# ---------------------------------------------------------------------------


def test_shifted_exponential_floor_and_mean():
    c = _cluster(delay_model="shifted_exponential",
                 delay_params={"tail_mean": 0.5})
    base = 100 * c.unit_time
    s = _samples(c.make_delay(), 4000)
    assert s.min() >= base  # the shift: never faster than the base
    np.testing.assert_allclose(s.mean(), base * 1.5, rtol=0.1)


def test_pareto_tail_heavier_than_exponential():
    """Matched medians, then compare tail ratios: the q99/q50 ratio of the
    Pareto model must dominate the shifted-exponential's."""
    pareto = _cluster(delay_model="pareto",
                      delay_params={"shape": 1.5, "scale": 0.5}).make_delay()
    expo = _cluster(delay_model="shifted_exponential",
                    delay_params={"tail_mean": 0.5}).make_delay()
    sp, se = _samples(pareto, 4000), _samples(expo, 4000)
    ratio_p = np.quantile(sp, 0.99) / np.quantile(sp, 0.5)
    ratio_e = np.quantile(se, 0.99) / np.quantile(se, 0.5)
    assert ratio_p > ratio_e, (ratio_p, ratio_e)


def test_markov_burstiness_and_stationary_fraction():
    p_slow, p_recover, factor = 0.1, 0.25, 8.0
    c = _cluster(delay_model="markov",
                 delay_params={"p_slow": p_slow, "p_recover": p_recover,
                               "slow_factor": factor})
    model = c.make_delay()
    s = _samples(model, 20000)
    base = 100 * c.unit_time
    slow = s > 2 * base  # only two levels exist: base and factor*base
    np.testing.assert_array_equal(np.unique(np.round(s / base, 6)),
                                  [1.0, factor])
    # Stationary slow fraction p_slow/(p_slow+p_recover) = 2/7.
    np.testing.assert_allclose(slow.mean(), p_slow / (p_slow + p_recover),
                               atol=0.03)
    # Burstiness: mean run length of slow stretches ~ 1/p_recover, far above
    # the ~1 an iid coin with the same rate would give.
    runs, cur = [], 0
    for flag in slow:
        if flag:
            cur += 1
        elif cur:
            runs.append(cur)
            cur = 0
    np.testing.assert_allclose(np.mean(runs), 1.0 / p_recover, rtol=0.25)


def test_markov_state_is_per_run():
    """make_delay() must hand out FRESH chain state: two runs with the same
    rng seed must see identical trajectories."""
    c = _cluster(delay_model="markov")
    a = _samples(c.make_delay(), 200, seed=3)
    b = _samples(c.make_delay(), 200, seed=3)
    np.testing.assert_array_equal(a, b)


def test_stateful_model_refused_on_legacy_delegation_path():
    """ClusterModel.compute_time caches ONE model instance, which would
    silently share markov chain state across runs -- it must refuse loudly
    instead (the engine path via make_delay keeps working)."""
    c = _cluster(delay_model="markov")
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="stateful"):
        c.compute_time(0, 100, rng)
    c.make_delay().compute_time(0, 100, rng)  # per-run path unaffected


def test_worker_aware_model_refused_on_legacy_delegation_path():
    """The legacy p2p_time signature cannot carry the worker index, so a
    per-link model must be refused loudly rather than silently timing every
    worker on the fast link."""
    c = _cluster(delay_model="bandwidth_coupled")
    with pytest.raises(ValueError, match="per.*worker|worker"):
        c.p2p_time(1000)
    assert c.make_delay().p2p_time(1000, 0) > c.make_delay().p2p_time(1000, 1)


# ---------------------------------------------------------------------------
# Vectorized / pre-sampled draws: the RNG stream must not move.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("delay,params", [
    ("constant", {}),
    ("shifted_exponential", {"tail_mean": 0.7}),
    ("pareto", {"shape": 1.8, "scale": 0.5}),
    ("markov", {}),
])
def test_sample_round_matches_scalar_draw_stream(delay, params):
    """One size-K ``sample_round`` draw must be bit-equal to K sequential
    ``compute_time`` calls in worker order -- the contract that lets the
    event executor vectorize per-round sampling (and the scan executor
    pre-sample whole streams) without moving any pinned trajectory."""
    c = _cluster(delay_model=delay, delay_params=params, jitter=0.2,
                 straggler_sigma=3.0)
    vec = c.make_delay().sample_round(100, np.random.default_rng(11))
    rng = np.random.default_rng(11)
    model = c.make_delay()
    scalars = np.asarray([model.compute_time(k, 100, rng) for k in range(K)])
    np.testing.assert_array_equal(vec, scalars)


def test_sample_stream_lockstep_matches_per_round_consumption():
    """A pre-sampled (rounds, K) lockstep stream consumes the RNG exactly
    like per-round ``sample_round`` calls (any model, stateful included)."""
    for delay in ("shifted_exponential", "markov"):
        c = _cluster(delay_model=delay)
        stream = c.make_delay().sample_stream(5, 100,
                                              np.random.default_rng(3),
                                              lockstep=True)
        rng = np.random.default_rng(3)
        model = c.make_delay()
        rows = np.stack([model.sample_round(100, rng) for _ in range(5)])
        np.testing.assert_array_equal(stream, rows)


def test_sample_stream_group_mode_refuses_order_dependent_models():
    """Group-family pre-sampling is only offered when the (round, worker)
    assignment cannot change the event executor's stream: vectorized or
    deterministic models yes, markov / jittered constant no."""
    rng = np.random.default_rng(0)
    assert _cluster(delay_model="pareto").make_delay().sample_stream(
        3, 10, rng) is not None
    assert _cluster().make_delay().sample_stream(3, 10, rng) is not None
    assert _cluster(jitter=0.5).make_delay().sample_stream(3, 10, rng) is None
    assert _cluster(delay_model="markov").make_delay().sample_stream(
        3, 10, rng) is None


def test_vector_sampled_flags():
    assert _cluster(delay_model="shifted_exponential").make_delay(
        ).vector_sampled
    assert _cluster(delay_model="pareto").make_delay().vector_sampled
    assert not _cluster().make_delay().vector_sampled
    assert not _cluster(delay_model="markov").make_delay().vector_sampled


def test_link_factors_expose_p2p_arithmetic():
    """``p2p_time(nbytes, k) == latency + nbytes * f_k / bandwidth`` exactly
    -- the expression in-graph executors replicate."""
    for delay, params in (("constant", {}),
                          ("bandwidth_coupled", {"link_slowdown": 8.0})):
        c = _cluster(delay_model=delay, delay_params=params)
        model = c.make_delay()
        f = model.link_factors()
        for k in range(K):
            assert model.p2p_time(4096, k) == \
                c.latency + 4096 * f[k] / c.bandwidth


# ---------------------------------------------------------------------------
# Bandwidth-coupled: delay billed on the compressor's own byte formula.
# ---------------------------------------------------------------------------


def test_bandwidth_coupled_link_slowdown():
    c = _cluster(delay_model="bandwidth_coupled",
                 delay_params={"link_slowdown": 20.0})
    model = c.make_delay()
    nbytes = 4096
    # Worker 0 is the straggler (ClusterModel.straggler_workers default).
    assert model.p2p_time(nbytes, 0) == c.latency + nbytes * 20.0 / c.bandwidth
    assert model.p2p_time(nbytes, 1) == c.latency + nbytes / c.bandwidth
    assert model.p2p_time(nbytes) == c.latency + nbytes / c.bandwidth
    # Compute stays the constant model's.
    rng = np.random.default_rng(0)
    assert model.compute_time(1, 100, rng) == 100 * c.unit_time


@pytest.mark.parametrize("name,kwargs", [
    ("dense", dict(rho=1.0)),
    ("topk_exact", dict(k=37, rho=0.1)),
    ("topk_q8", dict(k=37, rho=0.1)),
])
def test_bandwidth_coupled_agrees_with_compressor_billing(name, kwargs):
    """The bytes the delay model charges time for ARE the bytes the shared
    compressor formula bills -- the same payload_bytes() the transformer
    exchange path sums into exchange/bytes_step (tests/test_compressors.py
    pins that equivalence)."""
    comp = compress_lib.get_compressor(name)(**kwargs)
    c = _cluster(delay_model="bandwidth_coupled",
                 delay_params={"link_slowdown": 8.0})
    model = c.make_delay()
    d = 370
    wire = comp.wire_bytes(d)
    assert wire == int(comp.payload_bytes(comp.k if comp.k else d))
    assert model.p2p_time(wire, 0) == c.latency + wire * 8.0 / c.bandwidth


def test_bandwidth_coupled_rewards_sparsity_end_to_end():
    """Through the engine: with a slow link, sparser payloads must cut the
    straggler's upload time (comm_time), dense ones must pay full freight."""
    from repro.core import baselines, engine
    from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

    prob = make_linear_problem(
        LinearDatasetSpec(num_workers=K, n_per_worker=48, d=256,
                          nnz_per_row=16, seed=7), lam=1e-3)
    c = _cluster(straggler_sigma=1.0, delay_model="bandwidth_coupled",
                 delay_params={"link_slowdown": 50.0})
    sparse = baselines.acpd(K, 256, B=2, T=4, rho_d=16, gamma=0.5, H=16)
    dense = baselines.acpd_dense(K, B=2, T=4, gamma=0.5, H=16)
    r_sparse = engine.run_method(prob, sparse, c, num_outer=1, seed=0)
    r_dense = engine.run_method(prob, dense, c, num_outer=1, seed=0)
    assert r_sparse.records[-1].comm_time < r_dense.records[-1].comm_time


# ---------------------------------------------------------------------------
# Spec threading.
# ---------------------------------------------------------------------------


def test_cluster_delay_fields_round_trip_through_spec():
    from repro.api.spec import _cluster_from_dict, _cluster_to_dict

    c = _cluster(delay_model="markov",
                 delay_params={"p_slow": 0.2, "slow_factor": 4.0})
    d = _cluster_to_dict(c)
    assert d["delay_model"] == "markov"
    assert d["delay_params"] == {"p_slow": 0.2, "slow_factor": 4.0}
    assert _cluster_from_dict(d) == c
    # Old spec JSONs without the fields keep working (defaults).
    legacy = {k: v for k, v in d.items()
              if k not in ("delay_model", "delay_params")}
    back = _cluster_from_dict(legacy)
    assert back.delay_model == "constant" and back.delay_params == ()


def test_zoo_presets_round_trip():
    from repro import api
    from repro.api.presets import ZOO_DELAYS

    for delay in ZOO_DELAYS:
        spec = api.build_preset(f"zoo-{delay}", quick=True)
        assert api.ExperimentSpec.from_json(spec.to_json()) == spec
        assert spec.cluster.delay_model == delay
