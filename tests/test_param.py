"""Parameter plans + logical-axis sharding rules (models/param.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import param as pm


def _mesh():
    # 1-device CPU mesh with named axes of size 1: the rule machinery must
    # resolve identically (everything divisible by 1).
    from repro.launch.mesh import make_mesh

    return make_mesh((1, 1), ("data", "model"))


def test_spec_abstract_and_materialize():
    s = pm.ParamSpec((4, 8), jnp.float32, ("embed", "ff"))
    a = s.abstract()
    assert a.shape == (4, 8) and a.dtype == jnp.float32
    v = s.materialize(jax.random.key(0))
    assert v.shape == (4, 8)
    z = pm.ParamSpec((3,), jnp.float32, (None,), init="zeros").materialize(
        jax.random.key(0))
    assert float(jnp.abs(z).max()) == 0.0


def test_stack_specs_prepends_layers_axis():
    spec = {"w": pm.ParamSpec((4, 8), jnp.float32, ("embed", "ff"))}
    st = pm.stack_specs(spec, 5)
    assert st["w"].shape == (5, 4, 8)
    assert st["w"].axes == ("layers", "embed", "ff")


def test_divisibility_gate():
    # spec resolution only reads mesh.shape -- a stand-in works without
    # fabricating 4 devices in this 1-CPU process.
    import types
    mesh = types.SimpleNamespace(shape={"data": 1, "model": 4})
    ok = pm.ParamSpec((4, 8), jnp.float32, ("embed", "ff"))
    bad = pm.ParamSpec((4, 6), jnp.float32, ("embed", "ff"))  # 6 % 4 != 0
    assert pm.spec_to_pspec(ok, mesh) == P(None, "model")
    assert pm.spec_to_pspec(bad, mesh) == P(None, None)
    notes = pm.explain_sharding({"bad": bad}, mesh)
    assert len(notes) == 1 and "not divisible" in notes[0]


def test_rule_scope_overrides_and_restores():
    assert pm.get_active_rules() is pm.DEFAULT_RULES
    custom = {"batch": ("model",), "ff": None}
    with pm.rule_scope(custom):
        assert pm.get_active_rules() is custom
        with pm.rule_scope(None):
            assert pm.get_active_rules() is pm.DEFAULT_RULES
        assert pm.get_active_rules() is custom
    assert pm.get_active_rules() is pm.DEFAULT_RULES


def test_constraint_never_forces_replication():
    """A constraint with no resolvable axis must be a no-op (regression for
    the bug that replicated every activation -- EXPERIMENTS §Perf iter 1)."""
    mesh = _mesh()
    x = jnp.ones((6, 10))  # 6 % nothing relevant

    @jax.jit
    def f(x):
        return pm.constraint(x, mesh, "no_such_axis", None)

    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x))


def test_num_params():
    spec = {"a": pm.ParamSpec((4, 8), jnp.float32, (None, None)),
            "b": pm.ParamSpec((3,), jnp.float32, (None,))}
    assert pm.num_params(spec) == 35
