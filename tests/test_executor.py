"""Scan-fused executor: bit-for-bit equivalence with the event engine across
the protocol x delay zoo grid, the one-dispatch-per-run contract, eval-batch
bucketing, and the batched sweep runner."""

import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core import baselines, engine, executor
from repro.core.simulate import ClusterModel

K, D = 4, 256


def _cluster(delay="constant", delay_params=None, sigma=5.0, **kw):
    return ClusterModel(num_workers=K, straggler_sigma=sigma,
                        delay_model=delay,
                        delay_params=tuple((delay_params or {}).items()), **kw)


def _assert_runs_identical(got, want):
    assert len(got.records) == len(want.records)
    for rg, rw in zip(got.records, want.records):
        for f in dataclasses.fields(rg):
            a, b = getattr(rg, f.name), getattr(rw, f.name)
            assert a == b, (f.name, a, b, rg.iteration)
    np.testing.assert_array_equal(got.w, want.w)
    np.testing.assert_array_equal(got.alpha, want.alpha)
    if want.alpha_applied is None:
        assert got.alpha_applied is None
    else:
        np.testing.assert_array_equal(got.alpha_applied, want.alpha_applied)


def _run(problem, method, cluster, executor_name, *, num_outer=3,
         eval_every=2, seed=0):
    session = api.Session(problem, method, cluster, num_outer=num_outer,
                          eval_every=eval_every, seed=seed,
                          executor=executor_name)
    res = session.run()
    return res, session


# ---------------------------------------------------------------------------
# Bit-for-bit equivalence across the zoo grid.
# ---------------------------------------------------------------------------

# The four scan-capable protocols at zoo-preset shapes (scaled down).
_METHODS = {
    "sync": lambda: baselines.cocoa_plus(K, H=48),
    "cocoa": lambda: baselines.cocoa_v1(K, H=48),
    "cocoa_plus": lambda: baselines.cocoa_plus_solver(
        K, H=48, local_solver="accelerated"),
    "lag": lambda: baselines.acpd_lag(K, D, B=2, T=6, rho_d=32, gamma=0.5,
                                      H=48),
}

_ZOO_DELAYS = {
    "constant": {},
    "shifted_exponential": {"tail_mean": 1.0},
    "pareto": {"shape": 1.8, "scale": 0.5},
    "markov": {"p_slow": 0.1, "p_recover": 0.25, "slow_factor": 8.0},
    "bandwidth_coupled": {"link_slowdown": 20.0},
}


@pytest.mark.parametrize("delay", sorted(_ZOO_DELAYS))
@pytest.mark.parametrize("protocol", sorted(_METHODS))
def test_scan_matches_event_bit_for_bit(small_problem, protocol, delay):
    """The acceptance contract: executor='scan' reproduces executor='event'
    exactly -- trajectories, byte/time accounting, certificates -- for every
    supported (protocol, delay) zoo cell; the one unsupported cell
    (lag x markov, per-launch chain draws) must fall back loudly."""
    method = _METHODS[protocol]()
    cluster = _cluster(delay, _ZOO_DELAYS[delay],
                       sigma=1.0 if delay == "bandwidth_coupled" else 5.0)
    ok, why = executor.scan_supported(method, cluster)
    if not ok:
        assert (protocol, delay) == ("lag", "markov"), (protocol, delay, why)
        _, session = _run(small_problem, method, cluster, "auto",
                          num_outer=1)
        assert session.executor == "event"  # auto falls back
        with pytest.raises(ValueError, match="markov"):
            api.Session(small_problem, method, cluster, num_outer=1,
                        executor="scan")
        return
    ev, _ = _run(small_problem, method, cluster, "event")
    sc, session = _run(small_problem, method, cluster, "scan")
    assert session.executor == "scan"
    _assert_runs_identical(sc, ev)


@pytest.mark.parametrize("protocol", ["sync", "lag"])
def test_scan_handles_empty_round_budget(small_problem, protocol):
    """num_outer=0 must behave like the event executor: empty records,
    zero-initialized state, no crash."""
    res, _ = _run(small_problem, _METHODS[protocol](), _cluster(), "scan",
                  num_outer=0)
    assert res.records == []
    assert not res.w.any()


def test_scan_is_the_auto_choice_for_lockstep(small_problem):
    _, session = _run(small_problem, baselines.cocoa_plus(K, H=16),
                      _cluster(), "auto", num_outer=1)
    assert session.executor == "scan"


@pytest.mark.parametrize("protocol", ["group", "async", "adaptive_b"])
def test_event_protocols_stay_on_the_queue(small_problem, protocol):
    method = {
        "group": lambda: baselines.acpd(K, D, B=2, T=4, rho_d=32, H=16),
        "async": lambda: baselines.acpd_async(K, D, T=4, rho_d=32, H=16),
        "adaptive_b": lambda: baselines.acpd_adaptive(K, D, T=4, rho_d=32,
                                                      H=16),
    }[protocol]()
    _, session = _run(small_problem, method, _cluster(), "auto", num_outer=1)
    assert session.executor == "event"
    with pytest.raises(ValueError, match="executor='scan'"):
        api.Session(small_problem, method, _cluster(), num_outer=1,
                    executor="scan")


def test_scan_early_stop_routing(small_problem):
    """target_gap scans for lockstep (in-graph certificates + done mask);
    time_budget and non-lockstep early stop keep the event loop."""
    m = baselines.cocoa_plus(K, H=16)
    with pytest.raises(ValueError, match="executor='scan'"):
        api.Session(small_problem, m, _cluster(), num_outer=1,
                    executor="scan", time_budget=1.0)
    with pytest.raises(ValueError, match="unknown executor"):
        api.Session(small_problem, m, _cluster(), num_outer=1,
                    executor="fused")
    # auto + target_gap: lockstep scans, lag falls back to the event loop.
    s = api.Session(small_problem, m, _cluster(), num_outer=1,
                    target_gap=1e-12)
    assert s.executor == "scan"
    s = api.Session(small_problem, _METHODS["lag"](), _cluster(),
                    num_outer=1, target_gap=1e-12)
    assert s.executor == "event"
    with pytest.raises(ValueError, match="executor='scan'"):
        api.Session(small_problem, _METHODS["lag"](), _cluster(),
                    num_outer=1, executor="scan", target_gap=1e-12)
    # auto + time_budget: event for everyone.
    s = api.Session(small_problem, m, _cluster(), num_outer=1,
                    time_budget=1.0)
    assert s.executor == "event"
    # auto + target_gap caps the round budget: the gap scan computes masked
    # rounds to the end, so huge budgets stay on the stop-at-the-hit event
    # loop (forcing executor="scan" still overrides).
    big = executor.GAP_SCAN_AUTO_MAX_ROUNDS + 1
    s = api.Session(small_problem, m, _cluster(), num_outer=big,
                    target_gap=1e-12)
    assert s.executor == "event"
    s = api.Session(small_problem, m, _cluster(), num_outer=big,
                    target_gap=1e-12, executor="scan")
    assert s.executor == "scan"


@pytest.mark.parametrize("protocol", sorted(executor.LOCKSTEP_PROTOCOLS))
def test_target_gap_scan_matches_event_stream(small_problem, protocol):
    """The early-stop satellite contract: a target_gap run on the scan
    backend reproduces the event loop's streamed session exactly -- the
    same interleaved event sequence, the same truncation point, the same
    certificates -- both when the target is hit mid-run and when the budget
    completes first."""
    method = _METHODS[protocol]()
    # A target the run reaches partway: the 4th eval boundary's gap.
    probe, _ = _run(small_problem, method, _cluster(), "scan", num_outer=30,
                    eval_every=2)
    for target, want_reason in (
            (probe.records[3].gap * 1.0000001, "target_gap"),
            (probe.records[-1].gap * 0.5, "completed")):
        kw = dict(num_outer=30, eval_every=2, seed=0, target_gap=target)
        sessions = {}
        events = {}
        for exe in ("event", "scan"):
            sessions[exe] = api.Session(small_problem, method, _cluster(),
                                        executor=exe, **kw)
            events[exe] = list(sessions[exe])
        assert sessions["scan"].executor == "scan"
        assert [type(e) for e in events["event"]] == \
            [type(e) for e in events["scan"]]
        for a, b in zip(events["event"], events["scan"]):
            assert a == b, (a, b)
        assert events["scan"][-1].reason == want_reason
        _assert_runs_identical(sessions["scan"].result(),
                               sessions["event"].result())


def test_scan_session_streams_the_same_events(small_problem):
    """The executor axis must be invisible to event-stream consumers: same
    event types, same payloads, in the same order."""
    m = baselines.cocoa_plus(K, H=32)
    kw = dict(num_outer=4, eval_every=2, seed=1)
    ev = list(api.Session(small_problem, m, _cluster(), executor="event",
                          **kw))
    sc = list(api.Session(small_problem, m, _cluster(), executor="scan",
                          **kw))
    assert [type(e) for e in ev] == [type(e) for e in sc]
    for a, b in zip(ev, sc):
        assert a == b, (a, b)


# ---------------------------------------------------------------------------
# The one-dispatch-per-run contract.
# ---------------------------------------------------------------------------


@pytest.fixture
def dispatch_counter():
    """Snapshot executor.STATS around a test: compiled-call and retrace
    counts for the scan backends."""
    before = dict(executor.STATS)
    yield lambda: {k: executor.STATS[k] - before[k] for k in executor.STATS}


def test_lockstep_one_compiled_call_per_run(small_problem, dispatch_counter):
    m = baselines.cocoa_plus(K, H=16)
    for seed in range(3):
        _run(small_problem, m, _cluster(), "scan", num_outer=2, seed=seed)
    delta = dispatch_counter()
    assert delta["lockstep_calls"] == 3
    # Same shapes across seeds: at most ONE fresh trace for the whole batch.
    assert delta["lockstep_traces"] <= 1


def test_lag_one_compiled_call_per_run(small_problem, dispatch_counter):
    m = _METHODS["lag"]()
    for seed in range(2):
        _run(small_problem, m, _cluster(), "scan", num_outer=1, seed=seed)
    delta = dispatch_counter()
    assert delta["lag_calls"] == 2
    assert delta["lag_traces"] <= 1


def test_lag_scan_round_count_scales_free_of_dispatches(small_problem,
                                                        dispatch_counter):
    """More rounds must NOT mean more compiled calls (the whole point):
    double the budget, still one call."""
    m = _METHODS["lag"]()
    _run(small_problem, m, _cluster(), "scan", num_outer=2)
    assert dispatch_counter()["lag_calls"] == 1


# ---------------------------------------------------------------------------
# Deferred-eval bucketing.
# ---------------------------------------------------------------------------


def test_eval_bucket_sizes():
    assert [engine._bucket_size(n) for n in (1, 2, 3, 4, 5, 8, 9, 17)] == \
        [1, 2, 4, 4, 8, 8, 16, 32]


def test_eval_bucketing_reuses_one_compile(small_problem):
    """Snapshot counts within one power-of-two bucket must share a compiled
    eval (the retrace-per-count behavior this fixes), without moving any
    record value (lax.map rows are independent; pinned by the equivalence
    suite above)."""
    m = baselines.cocoa_plus(K, H=16)
    # Warm the 8-bucket (5 snapshots), then 6, 7, 8 must not retrace.
    _run(small_problem, m, _cluster(), "scan", num_outer=5, eval_every=1)
    cache = engine._eval_batched._cache_size()
    for outer in (6, 7, 8):
        _run(small_problem, m, _cluster(), "scan", num_outer=outer,
             eval_every=1)
    assert engine._eval_batched._cache_size() == cache


# ---------------------------------------------------------------------------
# The batched sweep runner.
# ---------------------------------------------------------------------------


def test_sweep_map_mode_is_bit_identical_to_single_runs(small_problem,
                                                        dispatch_counter):
    m = baselines.cocoa_plus(K, H=32)
    variants = api.run_lockstep_sweep(
        small_problem, m, _cluster(), num_outer=4, seeds=(0, 5),
        gammas=(1.0, 0.5), eval_every=2, batch="map")
    assert [(v.seed, v.gamma) for v in variants] == [
        (0, 1.0), (0, 0.5), (5, 1.0), (5, 0.5)]
    assert dispatch_counter()["sweep_calls"] == 1  # 4 runs, one dispatch
    for v in variants:
        single, _ = _run(small_problem, dataclasses.replace(m, gamma=v.gamma),
                         _cluster(), "scan", num_outer=4, eval_every=2,
                         seed=v.seed)
        _assert_runs_identical(v.result, single)


def test_sweep_vmap_mode_converges_deterministically(small_problem):
    m = baselines.cocoa_plus(K, H=32)
    a = api.run_lockstep_sweep(small_problem, m, _cluster(), num_outer=4,
                               seeds=(0, 1), eval_every=2)
    b = api.run_lockstep_sweep(small_problem, m, _cluster(), num_outer=4,
                               seeds=(0, 1), eval_every=2)
    for va, vb in zip(a, b):
        _assert_runs_identical(va.result, vb.result)
        assert va.result.records[-1].gap < va.result.records[0].gap
    # Seed sweeps share the method's timing model but not trajectories.
    assert a[0].result.records[-1].gap != a[1].result.records[-1].gap


def test_sweep_with_no_eval_boundaries(small_problem):
    """eval_every > num_outer: empty records per variant, like a Session
    with the same parameters (used to crash in the padded eval)."""
    m = baselines.cocoa_plus(K, H=16)
    variants = api.run_lockstep_sweep(small_problem, m, _cluster(),
                                      num_outer=2, seeds=(0,), eval_every=5)
    assert variants[0].result.records == []
    assert np.isfinite(variants[0].result.w).all()


def test_sweep_rejects_event_only_protocols(small_problem):
    with pytest.raises(ValueError, match="lockstep"):
        api.run_lockstep_sweep(small_problem,
                               baselines.acpd(K, D, H=16), _cluster(),
                               num_outer=1)


def test_sweep_spec_entry(small_problem):
    spec = api.build_preset("zoo-constant", quick=True)
    variants = api.sweep_spec(spec, "CoCoA+", seeds=(0, 1), batch="map")
    assert len(variants) == 2
    for v in variants:
        assert v.result.records[-1].gap < v.result.records[0].gap


# ---------------------------------------------------------------------------
# Spec threading.
# ---------------------------------------------------------------------------


def test_spec_executor_field_round_trips():
    spec = api.build_preset("zoo-constant", quick=True)
    assert spec.executor == "auto"
    forced = dataclasses.replace(spec, executor="event")
    back = api.ExperimentSpec.from_json(forced.to_json())
    assert back == forced
    # Old spec JSONs without the field keep working.
    d = spec.to_dict()
    del d["executor"]
    assert api.ExperimentSpec.from_dict(d).executor == "auto"


def test_experiment_threads_spec_executor(small_problem):
    spec = api.build_preset("zoo-constant", quick=True)
    exp = api.Experiment(dataclasses.replace(spec, executor="event"))
    assert exp.session(spec.methods[0]).executor == "event"
    exp = api.Experiment(spec)
    assert exp.session(spec.methods[0]).executor == "scan"  # CoCoA+ is sync
