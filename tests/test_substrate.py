"""Substrate: pipeline determinism/resume, checkpoint roundtrip, optimizers."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.optim.optimizers import (OptimizerConfig, apply_update,
                                    clip_by_global_norm, init_state, lr_at)


def test_pipeline_determinism_and_resume():
    cfg = get_config("qwen3-14b").reduced()
    p1 = TokenPipeline(cfg, batch_size=4, seq_len=32, seed=3)
    batches = [p1.next_batch() for _ in range(5)]
    # resume from step 3
    p2 = TokenPipeline(cfg, batch_size=4, seq_len=32, seed=3)
    p2.load_state_dict({"step": 3, "seed": 3})
    b3 = p2.next_batch()
    np.testing.assert_array_equal(np.asarray(batches[3]["tokens"]),
                                  np.asarray(b3["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(batches[0]["tokens"][:, 1:]),
                                  np.asarray(batches[0]["labels"][:, :-1]))


def test_pipeline_modalities():
    for arch in ("pixtral-12b", "hubert-xlarge"):
        cfg = get_config(arch).reduced()
        b = TokenPipeline(cfg, batch_size=2, seq_len=48, seed=0).next_batch()
        if arch == "pixtral-12b":
            assert b["patch_embeds"].shape[1] == cfg.num_patch_tokens
            assert b["tokens"].shape[1] == 48 - cfg.num_patch_tokens
        else:
            assert b["frame_embeds"].shape == (2, 48, cfg.d_model)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.int32)}}
    save_checkpoint(tmp_path, 7, tree, extra={"note": "x"})
    restored, extra = load_checkpoint(tmp_path, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    assert extra == {"note": "x"}


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, {"a": jnp.zeros(4)})


def test_schedules():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                          schedule="cosine")
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert abs(float(lr_at(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr_at(cfg, jnp.int32(100))) < 1e-6
    lin = OptimizerConfig(learning_rate=2.0, warmup_steps=0, total_steps=10,
                          schedule="linear")
    assert abs(float(lr_at(lin, jnp.int32(5))) - 1.0) < 1e-6


def test_grad_clip():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


@pytest.mark.parametrize("name", ["sgd", "adamw"])
def test_optimizer_descends_quadratic(name):
    cfg = OptimizerConfig(name=name, learning_rate=0.1, warmup_steps=0,
                          total_steps=200, schedule="constant",
                          weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_state(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
