"""MODEL_FLOPS conventions + the analytic HBM model (launch/{flops,analytic})."""

import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.analytic import hbm_bytes
from repro.launch.flops import active_params, model_flops

MESH = {"data": 16, "model": 16}


def test_active_params_moe_scaling():
    """qwen3-235b has ~22B ACTIVE of 235B total (top-8 of 128)."""
    cfg = get_config("qwen3-moe-235b-a22b")
    act = active_params(cfg)
    assert 18e9 < act < 26e9, act
    dense = get_config("qwen3-14b")
    # dense: active ~ total minus the input embedding table
    assert 13e9 < active_params(dense) < 15e9


def test_model_flops_conventions():
    cfg = get_config("qwen3-14b")
    n = active_params(cfg)
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert abs(tr - 6 * n * 256 * 4096) / tr < 1e-9
    assert abs(pf - 2 * n * 32 * 32768) / pf < 1e-9
    assert abs(dc - 2 * n * 128) / dc < 1e-9


def test_hbm_model_orderings():
    """Physical orderings the memory model must respect."""
    dense = get_config("qwen3-14b")
    big = get_config("qwen3-moe-235b-a22b")
    # train >> decode for the same arch
    tr = hbm_bytes(dense, INPUT_SHAPES["train_4k"], MESH)
    dc = hbm_bytes(dense, INPUT_SHAPES["decode_32k"], MESH)
    assert tr > 10 * dc
    # bigger model reads more at decode
    assert (hbm_bytes(big, INPUT_SHAPES["decode_32k"], MESH)
            > hbm_bytes(dense, INPUT_SHAPES["decode_32k"], MESH) * 0.5)
    # windowed arch's long-context decode is cheaper than a hypothetical
    # full-cache one: gemma3 long_500k cache traffic stays modest
    g3 = get_config("gemma3-27b")
    long_b = hbm_bytes(g3, INPUT_SHAPES["long_500k"], MESH)
    assert long_b < 20e9  # < 25 ms at 819 GB/s


def test_hbm_model_scales_with_mesh():
    cfg = get_config("qwen3-14b")
    single = hbm_bytes(cfg, INPUT_SHAPES["train_4k"], {"data": 16, "model": 16})
    multi = hbm_bytes(cfg, INPUT_SHAPES["train_4k"],
                      {"pod": 2, "data": 16, "model": 16})
    assert multi < single  # more devices -> less per-device traffic


def test_ssm_arch_supported():
    cfg = get_config("mamba2-780m")
    b = hbm_bytes(cfg, INPUT_SHAPES["train_4k"], MESH)
    assert np.isfinite(b) and b > 0
