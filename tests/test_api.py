"""The public API surface (repro/api): ExperimentSpec JSON round-trip,
streaming Session events + early stop, compat-wrapper equivalence, early
protocol validation, and the `python -m repro` CLI."""

import dataclasses
import json

import pytest

from repro import api
from repro.core import baselines, engine
from repro.core.acpd import run_method, run_method_reference
from repro.core.simulate import ClusterModel

K, D = 4, 512


def _tiny_spec(**overrides):
    """A seconds-scale spec against the session fixture problem's twin."""
    fields = dict(
        name="tiny",
        problem=api.ProblemSpec("linear_synthetic",
                                {"num_workers": K, "n_per_worker": 128,
                                 "d": D, "nnz_per_row": 24, "seed": 7,
                                 "lam": 1e-3}),
        cluster=ClusterModel(num_workers=K, straggler_sigma=3.0),
        methods=(
            api.MethodEntry(baselines.acpd(K, D, B=2, T=5, rho_d=32,
                                           gamma=0.5, H=64), 2),
            api.MethodEntry(baselines.cocoa_plus(K, H=64), 6),
        ),
        eval_every=2,
        seed=3,
    )
    fields.update(overrides)
    return api.ExperimentSpec(**fields)


# ---------------------------------------------------------------------------
# Spec serialization.
# ---------------------------------------------------------------------------


def test_spec_json_round_trip():
    spec = _tiny_spec(target_gap=1e-3, time_budget=12.5)
    text = spec.to_json()
    back = api.ExperimentSpec.from_json(text)
    assert back == spec
    # stable: serializing the round-tripped spec is byte-identical
    assert back.to_json() == text
    # every piece survives, including the nested config dataclasses
    assert back.methods[0].config == spec.methods[0].config
    assert back.cluster.straggler_workers == (0,)


def test_preset_specs_round_trip():
    for name in sorted(api.PRESETS):
        spec = api.build_preset(name, quick=True)
        assert api.ExperimentSpec.from_json(spec.to_json()) == spec, name


def test_problem_registry_errors():
    with pytest.raises(ValueError, match="unknown problem"):
        api.ProblemSpec("nope", {}).build()
    assert "rcv1_like" in api.available_problems()
    assert "linear_synthetic" in api.available_problems()


# ---------------------------------------------------------------------------
# Session streaming.
# ---------------------------------------------------------------------------


def test_session_folds_to_run_method_result(small_problem):
    """Draining a Session == the one-shot compat wrapper, record for record."""
    m = baselines.acpd(K, D, B=2, T=5, rho_d=64, gamma=0.5, H=64)
    cluster = ClusterModel(num_workers=K)
    want = run_method(small_problem, m, cluster, num_outer=2, eval_every=2,
                      seed=3)
    session = api.Session(small_problem, m, cluster, num_outer=2,
                          eval_every=2, seed=3)
    events = list(session.events())
    got = session.result()
    assert [dataclasses.astuple(r) for r in got.records] == \
        [dataclasses.astuple(r) for r in want.records]
    # the EvalEvent stream carries exactly the records
    evals = [e for e in events if isinstance(e, api.EvalEvent)]
    assert [e.to_record() for e in evals] == got.records


def test_session_stream_mode_matches_batched(small_problem):
    """Live (streamed) certificates == the deferred batched ones bit-for-bit
    (same contract tests/test_engine.py pins for replay vs batched)."""
    m = baselines.acpd(K, D, B=2, T=5, rho_d=64, gamma=0.5, H=64)
    cluster = ClusterModel(num_workers=K)
    batched = api.Session(small_problem, m, cluster, num_outer=2,
                          eval_every=2, seed=3).run()
    streamed = api.Session(small_problem, m, cluster, num_outer=2,
                           eval_every=2, seed=3, eval_mode="stream").run()
    assert [dataclasses.astuple(r) for r in streamed.records] == \
        [dataclasses.astuple(r) for r in batched.records]


def test_session_event_shape(small_problem):
    m = baselines.acpd(K, D, B=2, T=5, rho_d=64, gamma=0.5, H=64)
    session = api.Session(small_problem, m, ClusterModel(num_workers=K),
                          num_outer=2, eval_every=2, seed=0)
    events = list(session)
    rounds = [e for e in events if isinstance(e, api.RoundEvent)]
    syncs = [e for e in events if isinstance(e, api.SyncEvent)]
    stops = [e for e in events if isinstance(e, api.StopEvent)]
    assert len(rounds) == 2 * 5  # num_outer * T
    assert [s.iteration for s in syncs] == [5, 10]  # every T-th round
    assert len(stops) == 1 and stops[0].reason == "completed"
    assert isinstance(events[-1], api.StopEvent)
    # accounting is monotone along the stream
    ups = [e.bytes_up for e in rounds]
    assert ups == sorted(ups) and ups[-1] > 0


def test_session_early_stop_on_target_gap(small_problem):
    m = baselines.acpd(K, D, B=2, T=10, rho_d=64, gamma=0.5, H=256)
    full = api.Session(small_problem, m, ClusterModel(num_workers=K),
                       num_outer=6, eval_every=2, seed=0).run()
    target = full.records[len(full.records) // 2].gap  # reachable mid-run gap
    session = api.Session(small_problem, m, ClusterModel(num_workers=K),
                          num_outer=6, eval_every=2, seed=0,
                          target_gap=target)
    events = list(session)
    stop = events[-1]
    assert isinstance(stop, api.StopEvent) and stop.reason == "target_gap"
    res = session.result()
    assert res.records[-1].gap <= target
    assert res.records[-1].iteration < full.records[-1].iteration


def test_session_early_stop_on_time_budget(small_problem):
    m = baselines.acpd(K, D, B=2, T=10, rho_d=64, gamma=0.5, H=64)
    full = api.Session(small_problem, m, ClusterModel(num_workers=K),
                       num_outer=4, eval_every=4, seed=0).run()
    budget = full.records[-1].sim_time / 3
    session = api.Session(small_problem, m, ClusterModel(num_workers=K),
                          num_outer=4, eval_every=4, seed=0,
                          time_budget=budget)
    res = session.run()
    assert res.records, "early-stopped run still carries a terminal record"
    assert res.records[-1].sim_time >= budget  # stopped at the boundary
    assert res.records[-1].iteration < full.records[-1].iteration


def test_experiment_runs_spec(small_problem):
    spec = _tiny_spec()
    exp = api.Experiment(spec)
    results = exp.run()
    assert set(results) == {"ACPD", "CoCoA+"}
    # spec-driven run == direct run_method with the same knobs
    want = run_method(exp.problem, spec.methods[0].config, spec.cluster,
                      num_outer=2, eval_every=2, seed=3)
    got = results["ACPD"]
    assert [dataclasses.astuple(r) for r in got.records] == \
        [dataclasses.astuple(r) for r in want.records]
    assert spec.method_named("CoCoA+").num_outer == 6
    with pytest.raises(KeyError):
        spec.method_named("nope")


# ---------------------------------------------------------------------------
# Early protocol validation (satellite): unknown names fail fast, listing
# the registry.
# ---------------------------------------------------------------------------


def test_unknown_protocol_fails_fast_with_registry_listing(small_problem):
    m = dataclasses.replace(baselines.acpd(K, D), protocol="nope")
    with pytest.raises(ValueError, match=r"unknown protocol 'nope'.*group"):
        run_method(small_problem, m, ClusterModel(num_workers=K),
                   num_outer=1, seed=0)
    with pytest.raises(ValueError, match=r"unknown protocol 'nope'"):
        api.Session(small_problem, m, ClusterModel(num_workers=K),
                    num_outer=1)


def test_reference_error_mentions_engine_registry(small_problem):
    m = baselines.acpd_lag(K, D)
    with pytest.raises(ValueError, match=r"engine registry.*lag"):
        run_method_reference(small_problem, m, ClusterModel(num_workers=K),
                             num_outer=1, seed=0)


def test_sigma_prime_owned_by_protocols():
    """The sync/group defaults now come from Protocol classmethods."""
    m = baselines.cocoa_plus(K)  # sigma_prime pinned to K explicitly
    assert m.resolved_sigma_prime(K) == float(K)
    group = baselines.acpd(K, D, B=2, gamma=0.5)
    assert group.resolved_sigma_prime(K) == 0.5 * 2
    sync = dataclasses.replace(group, protocol="sync", sigma_prime=None)
    assert sync.resolved_sigma_prime(K) == 0.5 * K
    assert engine.get_protocol("sync").default_sigma_prime(group, K) == 0.5 * K
    with pytest.raises(ValueError, match="unknown protocol"):
        dataclasses.replace(group, protocol="nope").resolved_sigma_prime(K)


# ---------------------------------------------------------------------------
# CLI.
# ---------------------------------------------------------------------------


def test_cli_spec_and_run_round_trip(tmp_path, capsys):
    from repro.__main__ import main

    spec = _tiny_spec(target_gap=5e-2)
    spec_path = tmp_path / "tiny.json"
    spec.save(spec_path)
    out_path = tmp_path / "out.json"
    rc = main(["run", str(spec_path), "--out", str(out_path)])
    assert rc == 0
    printed = capsys.readouterr().out
    assert "eval" in printed and "stop" in printed
    doc = json.loads(out_path.read_text())
    assert doc["spec"] == spec.to_dict()
    assert "jax_version" in doc["provenance"]
    assert set(doc["results"]) == {"ACPD", "CoCoA+"}
    for res in doc["results"].values():
        assert res["records"], "each method carries its trajectory"


def test_cli_spec_subcommand(capsys):
    from repro.__main__ import main

    rc = main(["spec", "fig3", "--quick"])
    assert rc == 0
    text = capsys.readouterr().out
    spec = api.ExperimentSpec.from_json(text)
    assert spec.name.startswith("fig3-convergence")
    assert {e.config.protocol for e in spec.methods} == {"group", "sync"}
