"""Docs stay true: markdown links resolve offline, and the extension
guides' worked examples execute as-is (every fenced python block, in
order, in one namespace per guide)."""

import os
import pathlib
import re
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "scripts"))

import check_links  # noqa: E402  (scripts/check_links.py)

_PY_BLOCK = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _python_blocks(path: pathlib.Path) -> list[str]:
    return _PY_BLOCK.findall(path.read_text())


@pytest.mark.parametrize("md", ["README.md", "ROADMAP.md",
                                "docs/architecture.md",
                                "docs/extending-protocols.md",
                                "docs/extending-compressors.md",
                                "docs/performance.md",
                                "docs/serving.md",
                                "docs/fault-tolerance.md",
                                "docs/static-analysis.md"])
def test_markdown_links_resolve(md):
    path = ROOT / md
    assert path.exists(), md
    errors = check_links.check_file(path)
    assert not errors, "\n".join(errors)


@pytest.mark.parametrize("guide", ["docs/extending-protocols.md",
                                   "docs/extending-compressors.md",
                                   "docs/performance.md",
                                   "docs/serving.md",
                                   "docs/fault-tolerance.md",
                                   "docs/static-analysis.md"])
def test_extension_guide_examples_run_as_is(guide):
    """The acceptance bar for the guides: their code is real. All python
    blocks of a guide share one namespace and must run top to bottom
    (asserts inside the blocks are part of the documented behavior)."""
    blocks = _python_blocks(ROOT / guide)
    assert len(blocks) >= 2, f"{guide} lost its worked example"
    ns: dict = {}
    for i, block in enumerate(blocks):
        try:
            exec(compile(block, f"{guide}[block {i}]", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure reporting
            pytest.fail(f"{guide} block {i} failed: {e!r}\n{block}")


def test_serve_example_runs_quick():
    """The two-tenant serving demo is executed documentation: it must run at
    smoke scale and its own asserts (coalesce factor >= 2, i.e. the tenants
    actually shared one compiled batch) must hold."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "serve_experiments.py"),
         "--quick"],
        capture_output=True, text=True, cwd=ROOT, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "coalesce factor" in proc.stdout


def test_readme_documents_every_registry_entry():
    """The capability matrix must not rot: every registered protocol,
    compressor, delay model, fault model, and analysis rule appears in
    README.md."""
    from repro.analysis import lint
    from repro.core import compress, delays, engine, faults

    readme = (ROOT / "README.md").read_text()
    for name in (engine.available_protocols() + compress.available_compressors()
                 + delays.available_delays() + faults.available_faults()
                 + lint.available_rules()):
        if name.endswith(("_example", "-example")):
            continue  # registered by executing the guides' worked examples
        assert f"`{name}`" in readme, f"README does not mention `{name}`"
