"""Property-based invariants over the WHOLE protocol registry.

Every test auto-discovers ``engine.available_protocols()`` (minus doc-example
entries), so a newly registered server discipline is pinned to the engine
contract the moment it lands, without editing this file:

* clock/accounting monotonicity -- ``sim_time`` is nondecreasing and the
  byte/time totals are cumulative (the Protocol.process_round contract);
* per-round uplink bytes follow the ONE compressor formula
  (``Compressor.wire_bytes``) for every family whose billing is closed-form:
  lockstep allreduce phases, group-family arrivals x wire, LAG's
  heartbeat/payload mixture, partial_work's per-chunk streaming;
* sigma'-safety -- every registry entry resolves a positive, finite sigma'
  covering at least one aggregated contribution (>= gamma), and an explicit
  ``MethodConfig.sigma_prime`` always wins;
* event-vs-scan trajectory parity on randomized small specs wherever the
  protocol declares scan support (``executor.scan_supported``) -- the
  bit-identical-backends contract.

Runs under real hypothesis when installed (CI) and under the deterministic
fallback shim otherwise (see ``_hypothesis_compat``); either way every
property sweeps at least one example.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np
from _hypothesis_compat import given, settings, st

from repro import api
from repro.api.problems import ProblemSpec
from repro.api.spec import ExperimentSpec, MethodEntry
from repro.core import baselines
from repro.core import compress as compress_lib
from repro.core import engine
from repro.core import executor as executor_lib
from repro.core.simulate import ClusterModel

# One tiny shape shared by every example so jit caches hit across the sweep
# (seeds/B/delay params are data, not static arguments).
K, D, H, T = 4, 48, 8, 4
N_CHUNKS = 2

# Delay models cheap enough for property sweeps; markov exercises the
# stateful/host-adaptive lane, the others the vectorized lane.
_DELAYS = (
    ("constant", ()),
    ("shifted_exponential", (("tail_mean", 0.8),)),
    ("pareto", (("shape", 2.2), ("scale", 0.4))),
    ("markov", (("p_slow", 0.2), ("p_recover", 0.5), ("slow_factor", 4.0))),
)


def _registry_protocols() -> tuple[str, ...]:
    """Every registered protocol except doc-walkthrough examples."""
    return tuple(p for p in engine.available_protocols()
                 if not p.endswith(("_example", "-example")))


def _method_for(proto: str):
    """A small, valid MethodConfig for ``proto``.

    Known families use their baselines builder; an unknown (future) registry
    entry falls back to group-shaped defaults -- if those are invalid for it,
    the protocol's own __init__ raises and the test fails loudly, which is
    the correct prompt to teach this helper about the new family.
    """
    builders = {
        "sync": lambda: baselines.cocoa_plus(K, H=H),
        "cocoa": lambda: baselines.cocoa_v1(K, H=H),
        "cocoa_plus": lambda: baselines.cocoa_plus_solver(K, H=H),
        "group": lambda: baselines.acpd(K, D, B=2, T=T, rho_d=8, H=H),
        "async": lambda: baselines.acpd_async(K, D, T=T, rho_d=8, H=H),
        "lag": lambda: baselines.acpd_lag(K, D, B=2, T=T, rho_d=8, H=H),
        "adaptive_b": lambda: baselines.acpd_adaptive(K, D, T=T, rho_d=8,
                                                      H=H),
        "partial_work": lambda: baselines.acpd_partial_work(
            K, D, B=2, T=T, rho_d=8, H=H, n_chunks=N_CHUNKS),
        "hierarchical_b": lambda: baselines.acpd_hierarchical(
            K, D, T=T, rho_d=8, H=H, n_racks=2, rack_b=1),
    }
    if proto in builders:
        return builders[proto]()
    return dataclasses.replace(baselines.acpd(K, D, B=2, T=T, rho_d=8, H=H),
                               name=f"gen-{proto}", protocol=proto)


def _spec(proto: str, *, seed: int, delay: str, delay_params=(),
          num_outer: int = 2, executor: str = "event") -> ExperimentSpec:
    cfg = _method_for(proto)
    return ExperimentSpec(
        name=f"inv-{proto}-{delay}",
        problem=ProblemSpec("linear_synthetic",
                            {"num_workers": K, "n_per_worker": 24, "d": D,
                             "nnz_per_row": 6, "seed": 3, "lam": 1e-2,
                             "loss": "ridge"}),
        cluster=ClusterModel(num_workers=K, straggler_sigma=3.0,
                             delay_model=delay,
                             delay_params=tuple(delay_params)),
        methods=(MethodEntry(cfg, num_outer),),
        eval_every=num_outer * T, seed=seed, executor=executor).validate()


def _run_rounds(spec: ExperimentSpec):
    """Drain one session; returns (RoundEvents, RunResult, entry)."""
    entry = spec.methods[0]
    session = api.Experiment(spec).session(entry)
    rounds = [e for e in session.events() if isinstance(e, api.RoundEvent)]
    return rounds, session.result(), entry


# ---------------------------------------------------------------------------
# Clock + accounting monotonicity.
# ---------------------------------------------------------------------------


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(_DELAYS) - 1))
def test_clock_and_accounting_monotone(seed, delay_idx):
    """sim_time is nondecreasing and every total is cumulative, for every
    registry protocol under every sweep delay model."""
    delay, params = _DELAYS[delay_idx]
    for proto in _registry_protocols():
        rounds, _, _ = _run_rounds(_spec(proto, seed=seed, delay=delay,
                                         delay_params=params))
        assert rounds, proto
        prev = None
        for ev in rounds:
            assert ev.sim_time >= 0.0 and math.isfinite(ev.sim_time), proto
            assert ev.bytes_up >= 0 and ev.bytes_down >= 0, proto
            assert ev.compute_time >= 0.0 and ev.comm_time >= 0.0, proto
            if prev is not None:
                assert ev.sim_time >= prev.sim_time, proto
                assert ev.bytes_up >= prev.bytes_up, proto
                assert ev.bytes_down >= prev.bytes_down, proto
                assert ev.compute_time >= prev.compute_time, proto
                assert ev.comm_time >= prev.comm_time, proto
            prev = ev


# ---------------------------------------------------------------------------
# Per-round bytes == the compressor formula.
# ---------------------------------------------------------------------------


def _expected_initial_bytes(cls, cfg, wire: int) -> int:
    """Uplink bytes billed by ``initial_messages`` (before round 0)."""
    if issubclass(cls, engine.SyncProtocol):
        return 0  # lockstep tokens carry no payload
    if issubclass(cls, engine.PartialWorkProtocol):
        return K * max(1, cfg.n_chunks) * wire
    return K * wire  # group family: one full launch per worker


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(_DELAYS) - 1))
def test_round_bytes_follow_compressor_formula(seed, delay_idx):
    """Each round's uplink byte delta is the closed-form consequence of the
    method's compressor: wire_bytes per launched message, family by family.
    A family without a closed form still must bill nonnegatively."""
    delay, params = _DELAYS[delay_idx]
    for proto in _registry_protocols():
        spec = _spec(proto, seed=seed, delay=delay, delay_params=params)
        cfg = spec.methods[0].config
        cls = engine.get_protocol(proto)
        wire = compress_lib.for_method(cfg, D).wire_bytes(D)
        rounds, _, _ = _run_rounds(spec)
        prev_up = _expected_initial_bytes(cls, cfg, wire)
        assert rounds[0].bytes_up >= prev_up, proto
        for ev in rounds:
            delta = ev.bytes_up - prev_up
            prev_up = ev.bytes_up
            if issubclass(cls, engine.SyncProtocol):
                # Ring allreduce: reduce-scatter == all-gather phase, both
                # directions, every round.
                phase = (K - 1) * D * 4
                assert delta == phase, (proto, delta)
            elif issubclass(cls, engine.PartialWorkProtocol):
                # Every relaunched worker streams all n_chunks chunks, each
                # billed through the one compressor formula.
                per_pass = max(1, cfg.n_chunks) * wire
                assert delta % per_pass == 0, (proto, delta, per_pass)
                assert 0 <= delta <= K * per_pass, (proto, delta)
            elif issubclass(cls, engine.LagProtocol):
                # arrivals split into payloads (wire) and heartbeats (8B).
                hb = engine.LagProtocol.HEARTBEAT_BYTES
                lo, hi = ev.arrivals * hb, ev.arrivals * wire
                assert lo <= delta <= hi, (proto, delta, lo, hi)
                if wire != hb:
                    assert (delta - lo) % (wire - hb) == 0, (proto, delta)
            elif issubclass(cls, engine.GroupProtocol):
                # One full relaunch per arrival (group/async/adaptive_b/
                # hierarchical_b all share the reply-and-relaunch rule).
                assert delta == ev.arrivals * wire, (proto, delta,
                                                     ev.arrivals, wire)
            else:
                assert delta >= 0, (proto, delta)


# ---------------------------------------------------------------------------
# sigma'-safety.
# ---------------------------------------------------------------------------


def test_sigma_prime_safety():
    """Every registry entry resolves a positive finite sigma' covering at
    least one aggregated contribution (>= gamma); explicit overrides win."""
    for proto in _registry_protocols():
        cls = engine.get_protocol(proto)
        cfg = _method_for(proto)
        default = cls.default_sigma_prime(cfg, K)
        assert math.isfinite(default) and default > 0.0, (proto, default)
        assert default >= cfg.gamma - 1e-12, (proto, default, cfg.gamma)
        resolved = cfg.resolved_sigma_prime(K)
        if cfg.sigma_prime is not None:
            assert resolved == cfg.sigma_prime, proto
        else:
            assert resolved == default, (proto, resolved, default)
        forced = dataclasses.replace(cfg, sigma_prime=7.5)
        assert forced.resolved_sigma_prime(K) == 7.5, proto


def test_registry_hooks_present():
    """The registry contract the analyzer's registry-hooks rule enforces
    statically, checked dynamically: every entry answers the
    default_sigma_prime and coalesce_supported hooks with sane types."""
    for proto in _registry_protocols():
        cls = engine.get_protocol(proto)
        cfg = _method_for(proto)
        ok, why = cls.coalesce_supported(cfg, ClusterModel(num_workers=K))
        assert isinstance(ok, bool) and isinstance(why, str), proto
        assert ok or why, f"{proto}: refusal must carry a reason"


# ---------------------------------------------------------------------------
# Event-vs-scan trajectory parity.
# ---------------------------------------------------------------------------


def _assert_same_run(proto, a, b):
    assert len(a.records) == len(b.records), proto
    for ra, rb in zip(a.records, b.records):
        for f in dataclasses.fields(ra):
            va, vb = getattr(ra, f.name), getattr(rb, f.name)
            assert va == vb, (proto, f.name, va, vb)
    assert np.array_equal(np.asarray(a.w), np.asarray(b.w)), proto
    assert np.array_equal(np.asarray(a.alpha), np.asarray(b.alpha)), proto
    if a.alpha_applied is not None or b.alpha_applied is not None:
        assert np.array_equal(np.asarray(a.alpha_applied),
                              np.asarray(b.alpha_applied)), proto


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(_DELAYS) - 1))
def test_event_scan_parity(seed, delay_idx):
    """Wherever a protocol declares scan support for the spec's cluster,
    the two backends produce identical trajectories -- records AND final
    arrays.  Unsupported combinations must say why."""
    delay, params = _DELAYS[delay_idx]
    covered = 0
    for proto in _registry_protocols():
        spec = _spec(proto, seed=seed, delay=delay, delay_params=params)
        ok, why = executor_lib.scan_supported(spec.methods[0].config,
                                              spec.cluster)
        if not ok:
            assert why, proto  # a refusal always carries its reason
            continue
        covered += 1
        results = {}
        for ex in ("event", "scan"):
            s = api.Experiment(dataclasses.replace(spec, executor=ex)
                               ).session(spec.methods[0])
            s.run()
            assert s.executor == ex, proto
            results[ex] = s.result()
        _assert_same_run(proto, results["event"], results["scan"])
    if delay != "markov":  # markov is event-only by design
        assert covered > 0, "no scan-capable protocol exercised"
