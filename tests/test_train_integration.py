"""End-to-end training integration on the host mesh: loss goes down, ACPD and
dense exchanges both train, checkpoint/resume reproduces trajectories."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import InputShape, get_config
from repro.core import exchange as exch_lib
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import TrainSetup, build_train_step
from repro.models import model_spec
from repro.models.param import tree_materialize
from repro.optim.optimizers import OptimizerConfig, init_state


def _train(exchange, steps=14, seed=0):
    cfg = get_config("codeqwen1.5-7b").reduced()
    mesh = make_host_mesh()
    shape = InputShape("t", 64, 8, "train")
    opt = OptimizerConfig(learning_rate=1e-3, warmup_steps=2, total_steps=steps)
    setup = TrainSetup(cfg=cfg, optimizer=opt, exchange=exchange,
                       seq_shard=False, zero1=False, fsdp=False)
    jitted, _, _ = build_train_step(setup, mesh, shape)
    params = tree_materialize(model_spec(cfg), jax.random.key(seed))
    opt_state = init_state(opt, params)
    exch_state = (exch_lib.init_state(exchange, params)
                  if exchange is not None else None)
    pipe = TokenPipeline(cfg, 8, 64, seed=seed)
    losses = []
    with mesh:
        for _ in range(steps):
            batch = pipe.next_batch()
            params, opt_state, exch_state, m = jitted(
                params, opt_state, exch_state, batch)
            losses.append(float(m["loss"]))
    return losses, params


def test_plain_dp_loss_decreases():
    losses, _ = _train(None, steps=20)
    assert losses[-1] < losses[0] - 0.15
    assert all(np.isfinite(l) for l in losses)


def test_acpd_exchange_trains():
    # Sparse B-of-K exchange ramps slower than dense DP while the error
    # feedback warms up (paper Fig. 3 col 1) -- give it a few more steps.
    exch = exch_lib.ExchangeConfig(num_groups=4, group_size=2, sync_period=5,
                                   rho=0.05, gamma=0.9)
    losses, _ = _train(exch, steps=24)
    assert losses[-1] < losses[0] - 0.1
    assert all(np.isfinite(l) for l in losses)


def test_dense_exchange_matches_plain():
    """dense_config exchange must track plain DP closely (same math modulo
    vmapped-grad grouping vs single grad; identical in exact arithmetic)."""
    l_plain, p_plain = _train(None, steps=8, seed=1)
    l_dense, p_dense = _train(exch_lib.dense_config(4), steps=8, seed=1)
    np.testing.assert_allclose(l_plain, l_dense, rtol=2e-3, atol=2e-3)
    a = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(p_plain)])
    b = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(p_dense)])
    # atol covers the float32 accumulation difference between the vmapped
    # grouped gradient and the single fused gradient (a handful of params in
    # the 1.5M drift by ~1e-3 after 8 Adam steps; backend-dependent).
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3,
                               atol=2e-3)
