"""Flash attention custom-VJP vs naive reference: forward + all gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.flash import FlashSpec, flash_attention


def naive(q, k, v, causal, window, softcap):
    B, S, KV, G, hd = q.shape
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= qpos - kpos < window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(q.dtype), v)


CASES = [
    dict(S=96, causal=True, window=None, softcap=None, bq=32),
    dict(S=64, causal=True, window=16, softcap=None, bq=16),
    dict(S=100, causal=True, window=None, softcap=50.0, bq=32),  # pad + cap
    dict(S=80, causal=False, window=None, softcap=None, bq=32),  # encoder
    dict(S=128, causal=True, window=48, softcap=None, bq=32),  # win != bk mult
    dict(S=33, causal=True, window=None, softcap=None, bq=32),  # 1 ragged blk
]


@pytest.mark.parametrize("case", CASES, ids=lambda c: f"S{c['S']}w{c['window']}")
def test_flash_matches_naive(case):
    rng = np.random.default_rng(case["S"])
    B, KV, G, hd = 2, 2, 3, 16
    S = case["S"]
    q = jnp.asarray(rng.standard_normal((B, S, KV, G, hd)).astype(np.float32)) * 0.3
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32)) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32))
    spec = FlashSpec(case["causal"], case["window"], case["bq"], case["bq"],
                     case["softcap"])
    args = (case["causal"], case["window"], case["softcap"])

    o1 = flash_attention(q, k, v, spec)
    o2 = naive(q, k, v, *args)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)

    f = lambda *a: jnp.sum(jnp.sin(flash_attention(*a, spec)))
    g = lambda *a: jnp.sum(jnp.sin(naive(*a, *args)))
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, "q k v".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4,
                                   err_msg=f"d{name}")


def test_block_size_invariance():
    """Output must not depend on the tiling."""
    rng = np.random.default_rng(0)
    B, S, KV, G, hd = 1, 256, 2, 2, 8
    q = jnp.asarray(rng.standard_normal((B, S, KV, G, hd)).astype(np.float32)) * 0.2
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32)) * 0.2
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)).astype(np.float32))
    outs = [flash_attention(q, k, v, FlashSpec(True, None, bq, bk, None))
            for bq, bk in [(32, 32), (64, 128), (256, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


def test_windowed_flops_path_used():
    """Windowed layout must tile only window+bq keys per query block."""
    from repro.models.flash import _layout
    spec = FlashSpec(True, 1024, 512, 512, None)
    bq, nq, bk, nk, wpad, Lk, windowed = _layout(spec, 32768)
    assert windowed and Lk == 1024 + 512 and nk == 3  # not 64 blocks
