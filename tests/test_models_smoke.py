"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
variant (<=2 periods, d_model<=256, <=4 experts), one train step on CPU with
shape + finiteness assertions, plus a decode step where the family supports it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decode_step, model_spec, prefill, train_loss
from repro.models.param import num_params, tree_materialize


def _batch(cfg, B, S, key):
    if cfg.frontend == "text":
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        return {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision_stub":
        P = cfg.num_patch_tokens
        tokens = jax.random.randint(key, (B, S - P), 0, cfg.vocab_size)
        patches = jax.random.normal(key, (B, P, cfg.d_model)) * 0.02
        return {"tokens": tokens, "labels": tokens, "patch_embeds": patches}
    frames = jax.random.normal(key, (B, S, cfg.d_model)) * 0.02
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return {"frame_embeds": frames, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 256 and cfg.num_experts <= 4
    params = tree_materialize(model_spec(cfg), jax.random.key(0))
    batch = _batch(cfg, B := 2, S := 64, jax.random.key(1))

    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    assert float(loss) < 2.0 + np.log(cfg.vocab_size)  # near-uniform at init
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    # grads mirror params exactly
    assert jax.tree.structure(grads) == jax.tree.structure(params)
    for g, p in zip(leaves, jax.tree.leaves(params)):
        assert g.shape == p.shape


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).supports_decode()])
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    params = tree_materialize(model_spec(cfg), jax.random.key(0))
    B, S_ctx, S_max = 2, 40, 56
    batch = {k: v for k, v in _batch(cfg, B, S_ctx, jax.random.key(2)).items()
             if k != "labels"}
    logits, caches, plen = prefill(params, batch, cfg, max_seq=S_max)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for t in range(3):
        logits, caches = decode_step(params, tok, caches,
                                     jnp.int32(plen + 1 + t), cfg)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_full_config_param_counts():
    """The FULL configs must match their nameplate sizes (never allocated --
    counted from the ParamSpec plan)."""
    expected = {
        "pixtral-12b": 12.3e9,
        "qwen3-moe-30b-a3b": 30.5e9,
        "jamba-1.5-large-398b": 398e9,
        "mamba2-780m": 0.86e9,
        "qwen3-moe-235b-a22b": 235e9,
        "hubert-xlarge": 1.26e9,
        "qwen3-14b": 14.8e9,
        "phi3-medium-14b": 14.7e9,
        "gemma3-27b": 28.4e9,
        "codeqwen1.5-7b": 8.2e9,
    }
    for arch, want in expected.items():
        got = num_params(model_spec(get_config(arch)))
        assert abs(got - want) / want < 0.08, (arch, got, want)
