"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# topk_filter kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [257, 1024, 4096, 50000])
@pytest.mark.parametrize("k_frac", [0.001, 0.02, 0.25])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_kernel_sweep(d, k_frac, dtype):
    rng = np.random.default_rng(d)
    k = max(1, int(k_frac * d))
    x = jnp.asarray(rng.standard_normal(d).astype(np.float32)).astype(dtype)
    sent, resid, mask = ops.topk_filter(x, k)
    s_ref, r_ref, m_ref = ref.topk_filter_ref(x, k)
    # exact contracts
    assert int(mask.sum()) == k
    assert bool(jnp.all(sent + resid == x))  # bitwise conservation
    # value contract: kept mass within one refined bucket of exact top-k
    mass = float(jnp.abs(sent.astype(jnp.float32)).sum())
    mass_ref = float(jnp.abs(s_ref.astype(jnp.float32)).sum())
    assert mass >= 0.999 * mass_ref


@settings(max_examples=15, deadline=None)
@given(st.integers(64, 3000), st.integers(0, 2**31 - 1))
def test_topk_kernel_property(d, seed):
    rng = np.random.default_rng(seed)
    k = max(1, d // 17)
    x = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    sent, resid, mask = ops.topk_filter(x, k)
    assert int(mask.sum()) == k
    assert bool(jnp.all(sent + resid == x))
    kept_min = float(jnp.min(jnp.where(mask, jnp.abs(x), jnp.inf)))
    drop_max = float(jnp.max(jnp.where(mask, 0.0, jnp.abs(x))))
    # banded contract: kept >= dropped up to one refined bucket. The ladder
    # spans 2^22 in 64 buckets, so the refined bucket ratio is
    # exp(ln(2^22)/63^2) ~ 1.004 -> allow 0.6%.
    assert kept_min >= drop_max * (1 - 6e-3) - 1e-6


def test_topk_kernel_few_nonzeros():
    """k above the number of non-negligible entries: keep what exists."""
    x = jnp.zeros(2048).at[jnp.array([3, 500, 1999])].set(
        jnp.array([1.0, -2.0, 0.5]))
    sent, resid, mask = ops.topk_filter(x, 100)
    assert int(mask.sum()) <= 100
    kept = set(np.flatnonzero(np.asarray(sent)).tolist())
    assert {3, 500, 1999} <= kept
    assert bool(jnp.all(sent + resid == x))


# ---------------------------------------------------------------------------
# sdca_inner kernel.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,n_k,d,H", [(1, 32, 128, 64), (4, 64, 256, 200),
                                       (3, 128, 512, 150), (8, 16, 1024, 50)])
def test_sdca_kernel_sweep(K, n_k, d, H):
    rng = np.random.default_rng(K * 1000 + n_k)
    X = jnp.asarray(rng.standard_normal((K, n_k, d)).astype(np.float32)) / np.sqrt(d)
    y = jnp.asarray(np.sign(rng.standard_normal((K, n_k))).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((K, d)).astype(np.float32) * 0.1)
    alpha = jnp.asarray(rng.standard_normal((K, n_k)).astype(np.float32) * 0.05)
    norms = jnp.sum(X * X, axis=-1)
    idx = jnp.asarray(rng.integers(0, n_k, (K, H)).astype(np.int32))
    lam, n, sp = 1e-3, K * n_k, 2.0
    da_k, v_k = ops.sdca_epoch(w, alpha, X, y, norms, lam, n, sp, idx)
    da_r, v_r = ref.sdca_inner_ref(w, alpha, X, y, norms, lam, n, sp, idx)
    np.testing.assert_allclose(np.asarray(da_k), np.asarray(da_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_k), np.asarray(v_r),
                               rtol=1e-5, atol=1e-6)


def test_sdca_kernel_capacity_fallback():
    """Over-VMEM partitions must transparently use the jnp path."""
    K, n_k, d, H = 1, 64, 70000, 8  # n_k*d > 4M elements
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((K, n_k, d)).astype(np.float32)) * 0.01
    y = jnp.ones((K, n_k), jnp.float32)
    norms = jnp.sum(X * X, axis=-1)
    idx = jnp.zeros((K, H), jnp.int32)
    da, v = ops.sdca_epoch(jnp.zeros((K, d)), jnp.zeros((K, n_k)), X, y,
                           norms, 1e-3, 64, 1.0, idx)
    assert np.isfinite(np.asarray(da)).all()
