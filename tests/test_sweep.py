"""The universal sweep runner (api.run_sweep): protocol x delay x seed x
gamma grids as one compiled call, the shard axis, the eligibility matrix,
and the grid-shape retrace contract.

The single-run executor equivalence suite lives in tests/test_executor.py;
this module pins the SWEEP layer on top of it: per-cell bit-identity of
``batch="map"`` sweeps against ``Session(executor="scan")`` (and therefore
against the event engine), delay-axis batching for lag, pow2 cell padding,
and -- in a 4-fake-device subprocess -- that ``shard="cells"`` changes
nothing but the wall clock.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import api
from repro.core import baselines, executor
from repro.core.simulate import ClusterModel

K, D = 4, 256

# The delay axis used across this module: every pre-sampleable zoo entry.
SWEEP_DELAYS = (("constant", {}),
                ("shifted_exponential", {"tail_mean": 1.0}),
                ("pareto", {"shape": 1.8, "scale": 0.5}))


def _cluster(delay="constant", delay_params=None, sigma=5.0):
    return ClusterModel(num_workers=K, straggler_sigma=sigma,
                        delay_model=delay,
                        delay_params=tuple((delay_params or {}).items()))


def _lag():
    return baselines.acpd_lag(K, D, B=2, T=6, rho_d=32, gamma=0.5, H=48)


def _assert_result_identical(got, want):
    assert len(got.records) == len(want.records)
    for rg, rw in zip(got.records, want.records):
        assert rg == rw, (rg, rw)
    np.testing.assert_array_equal(got.w, want.w)
    np.testing.assert_array_equal(got.alpha, want.alpha)
    if want.alpha_applied is not None:
        np.testing.assert_array_equal(got.alpha_applied, want.alpha_applied)


@pytest.fixture
def dispatch_counter():
    before = dict(executor.STATS)
    yield lambda: {k: executor.STATS[k] - before[k] for k in executor.STATS}


# ---------------------------------------------------------------------------
# The acceptance grid: lag x delay x seed, ONE compiled call, bit-identical.
# ---------------------------------------------------------------------------


def test_lag_delay_seed_grid_is_one_call_and_bit_identical(small_problem,
                                                           dispatch_counter):
    """The tentpole contract: a lag x (constant, shifted_exponential,
    pareto) x 4-seed grid runs as ONE compiled call and, under
    ``batch="map"``/``shard="none"``, every cell is bit-identical to its
    per-cell ``Session`` run."""
    m = _lag()
    variants = api.run_sweep(small_problem, m, _cluster(), num_outer=2,
                             seeds=(0, 1, 2, 3), delays=SWEEP_DELAYS,
                             eval_every=2, batch="map", shard="none")
    delta = dispatch_counter()
    assert delta["sweep_lag_calls"] == 1  # 12 runs, one dispatch
    assert len(variants) == 12
    assert [(v.delay, v.seed) for v in variants[:5]] == [
        ("constant", 0), ("constant", 1), ("constant", 2), ("constant", 3),
        ("shifted_exponential", 0)]
    for v in variants:
        single = api.Session(
            small_problem, m, _cluster(v.delay, dict(SWEEP_DELAYS)[v.delay]),
            num_outer=2, eval_every=2, seed=v.seed, executor="scan").run()
        _assert_result_identical(v.result, single)


def test_lockstep_delay_axis_rides_free(small_problem, dispatch_counter):
    """Lockstep cells share trajectories across the delay axis (timing is
    host accounting), so the delay axis multiplies variants but not
    compiled work -- and each variant still matches its single run."""
    m = baselines.cocoa_plus(K, H=32)
    variants = api.run_sweep(small_problem, m, _cluster(), num_outer=4,
                             seeds=(0, 5), gammas=(1.0, 0.5),
                             delays=SWEEP_DELAYS, eval_every=2, batch="map",
                             shard="none")
    assert dispatch_counter()["sweep_calls"] == 1
    assert len(variants) == 12  # 3 delays x 2 seeds x 2 gammas
    seen = set()
    for v in variants:
        seen.add((v.delay, v.seed, v.gamma))
        single = api.Session(
            small_problem, dataclasses.replace(m, gamma=v.gamma),
            _cluster(v.delay, dict(SWEEP_DELAYS)[v.delay]),
            num_outer=4, eval_every=2, seed=v.seed, executor="scan").run()
        _assert_result_identical(v.result, single)
    assert len(seen) == 12
    # Same (seed, gamma), different delay: identical trajectory, different
    # simulated clock.
    a = next(v for v in variants if (v.delay, v.seed, v.gamma)
             == ("constant", 0, 1.0))
    b = next(v for v in variants if (v.delay, v.seed, v.gamma)
             == ("pareto", 0, 1.0))
    np.testing.assert_array_equal(a.result.w, b.result.w)
    assert a.result.records[-1].sim_time != b.result.records[-1].sim_time


def test_lag_sweep_distinguishes_same_delay_different_params(small_problem):
    """Regression: two entries of the SAME delay model with different params
    must each get their own duration stream (the cache used to key by name
    alone, silently reusing the first entry's timing)."""
    m = _lag()
    pa, pb = {"shape": 1.8, "scale": 0.5}, {"shape": 1.1, "scale": 5.0}
    variants = api.run_sweep(small_problem, m, _cluster(), num_outer=1,
                             seeds=(0,), delays=(("pareto", pa),
                                                 ("pareto", pb)),
                             eval_every=2, batch="map", shard="none")
    assert len(variants) == 2
    for v, params in zip(variants, (pa, pb)):
        single = api.Session(small_problem, m, _cluster("pareto", params),
                             num_outer=1, eval_every=2, seed=0,
                             executor="scan").run()
        _assert_result_identical(v.result, single)
    assert (variants[0].result.records[-1].sim_time
            != variants[1].result.records[-1].sim_time)


def test_lag_sweep_rejects_unsampleable_delay(small_problem):
    with pytest.raises(ValueError, match="markov"):
        api.run_sweep(small_problem, _lag(), _cluster(), num_outer=1,
                      delays=("constant", ("markov", {"p_slow": 0.1})))


def test_run_sweep_rejects_group_family(small_problem):
    with pytest.raises(ValueError, match="scan-capable"):
        api.run_sweep(small_problem, baselines.acpd(K, D, H=16), _cluster(),
                      num_outer=1)


def test_run_sweep_rejects_empty_axes(small_problem):
    m = baselines.cocoa_plus(K, H=16)
    for kw in (dict(seeds=()), dict(gammas=()), dict(delays=())):
        with pytest.raises(ValueError, match="empty"):
            api.run_sweep(small_problem, m, _cluster(), num_outer=1, **kw)


# ---------------------------------------------------------------------------
# Grid-shape retrace contract (the pow2 cell-padding satellite).
# ---------------------------------------------------------------------------


def test_grid_shapes_within_a_bucket_share_one_compile(small_problem,
                                                       dispatch_counter):
    """Distinct (n_seeds, n_gammas) grids used to retrace per shape; with
    the cell axis padded to pow2 buckets, every grid that lands in the same
    bucket reuses one compile (and bigger grids add at most log-many)."""
    m = baselines.cocoa_plus(K, H=16)
    api.run_sweep(small_problem, m, _cluster(), num_outer=3, seeds=(0, 1, 2),
                  eval_every=2, batch="map", shard="none")  # warm the 4-bucket
    warm = dict(executor.STATS)
    grids = [dict(seeds=(0,), gammas=(1.0, 0.7, 0.4, 0.2)),
             dict(seeds=(0, 1), gammas=(1.0, 0.5)),
             dict(seeds=(0, 1, 2, 3))]
    for g in grids:
        api.run_sweep(small_problem, m, _cluster(), num_outer=3,
                      eval_every=2, batch="map", shard="none", **g)
    assert executor.STATS["sweep_traces"] == warm["sweep_traces"]
    assert executor.STATS["sweep_calls"] == warm["sweep_calls"] + 3
    # The eval axis buckets the same way: cadences whose boundary counts
    # land in one pow2 bucket share the compile too.
    api.run_sweep(small_problem, m, _cluster(), num_outer=9, seeds=(0, 1),
                  eval_every=2, batch="map", shard="none")  # 4 boundaries
    warm_eval = executor.STATS["sweep_traces"]
    api.run_sweep(small_problem, m, _cluster(), num_outer=9, seeds=(0, 1),
                  eval_every=3, batch="map", shard="none")  # 3 -> pads to 4
    assert executor.STATS["sweep_traces"] == warm_eval
    # The same contract holds for the lag grid (its own jit entry).
    mlag = _lag()
    api.run_sweep(small_problem, mlag, _cluster(), num_outer=1,
                  seeds=(0, 1, 2), eval_every=2, batch="map", shard="none")
    warm_lag = executor.STATS["sweep_lag_traces"]
    api.run_sweep(small_problem, mlag, _cluster(), num_outer=1,
                  seeds=(5, 6, 7, 8), eval_every=2, batch="map", shard="none")
    assert executor.STATS["sweep_lag_traces"] == warm_lag


# ---------------------------------------------------------------------------
# The eligibility matrix: protocol x delay x executor x shard.
# ---------------------------------------------------------------------------

# Where every (protocol, delay) cell must route under executor="auto", and
# which shard axes a sweep of it may use on a multi-device host.  This is the
# full current registry; a new protocol/delay entry must extend it (the
# completeness asserts below fail otherwise), so routing can never regress
# silently.
_EXPECTED_EXECUTOR = {
    # protocol: {delay: "scan" | "event"}
    "sync": dict.fromkeys(
        ["constant", "shifted_exponential", "pareto", "markov",
         "bandwidth_coupled"], "scan"),
    "cocoa": dict.fromkeys(
        ["constant", "shifted_exponential", "pareto", "markov",
         "bandwidth_coupled"], "scan"),
    "cocoa_plus": dict.fromkeys(
        ["constant", "shifted_exponential", "pareto", "markov",
         "bandwidth_coupled"], "scan"),
    "lag": {"constant": "scan", "shifted_exponential": "scan",
            "pareto": "scan", "bandwidth_coupled": "scan",
            "markov": "event"},
    "group": dict.fromkeys(
        ["constant", "shifted_exponential", "pareto", "markov",
         "bandwidth_coupled"], "event"),
    "async": dict.fromkeys(
        ["constant", "shifted_exponential", "pareto", "markov",
         "bandwidth_coupled"], "event"),
    "adaptive_b": dict.fromkeys(
        ["constant", "shifted_exponential", "pareto", "markov",
         "bandwidth_coupled"], "event"),
    # partial_work scans solo when the (round, chunk, worker) duration
    # stream is pre-sampleable (lag's rule, per chunk); markov's stateful
    # per-launch draws keep the event queue.  Membership schedules and
    # pw_quantum also force event, but the matrix row is the static-cluster
    # default (those cases are pinned in tests/test_partial_work.py).
    "partial_work": {"constant": "scan", "shifted_exponential": "scan",
                     "pareto": "scan", "bandwidth_coupled": "scan",
                     "markov": "event"},
    # Rack-dependent pop counts are host-adaptive: always the event queue.
    "hierarchical_b": dict.fromkeys(
        ["constant", "shifted_exponential", "pareto", "markov",
         "bandwidth_coupled"], "event"),
}

_ZOO_PARAMS = {
    "constant": {},
    "shifted_exponential": {"tail_mean": 1.0},
    "pareto": {"shape": 1.8, "scale": 0.5},
    "markov": {"p_slow": 0.1, "p_recover": 0.25, "slow_factor": 8.0},
    "bandwidth_coupled": {"link_slowdown": 20.0},
}

_MATRIX_METHODS = {
    "sync": lambda: baselines.cocoa_plus(K, H=16),
    "cocoa": lambda: baselines.cocoa_v1(K, H=16),
    "cocoa_plus": lambda: baselines.cocoa_plus_solver(
        K, H=16, local_solver="accelerated"),
    "lag": lambda: baselines.acpd_lag(K, D, B=2, T=4, rho_d=32, gamma=0.5,
                                      H=16),
    "group": lambda: baselines.acpd(K, D, B=2, T=4, rho_d=32, H=16),
    "async": lambda: baselines.acpd_async(K, D, T=4, rho_d=32, H=16),
    "adaptive_b": lambda: baselines.acpd_adaptive(K, D, T=4, rho_d=32, H=16),
    "partial_work": lambda: baselines.acpd_partial_work(
        K, D, B=2, T=4, rho_d=32, H=16, n_chunks=2),
    "hierarchical_b": lambda: baselines.acpd_hierarchical(
        K, D, T=4, rho_d=32, H=16, n_racks=2, rack_b=1),
}


def test_eligibility_matrix_is_complete():
    """The expectation table must cover the full current registries."""
    from repro.core import delays as delays_lib
    from repro.core import engine as engine_lib

    protocols = {p for p in engine_lib.available_protocols()
                 if not p.endswith("_example")}
    assert protocols == set(_EXPECTED_EXECUTOR), (
        "a protocol entered/left the registry; extend the eligibility matrix")
    delays = {d for d in delays_lib.available_delays()
              if not d.endswith("_example")}
    for protocol, row in _EXPECTED_EXECUTOR.items():
        assert set(row) == delays, (
            f"delay registry changed; extend the {protocol!r} matrix row")


@pytest.mark.parametrize("protocol", sorted(_EXPECTED_EXECUTOR))
def test_eligibility_matrix_executor_routing(small_problem, protocol):
    """executor='auto' routes every (protocol, delay) cell exactly as the
    matrix says -- constructing the Session, not just asking the predicate."""
    for delay, want in _EXPECTED_EXECUTOR[protocol].items():
        method = _MATRIX_METHODS[protocol]()
        cluster = _cluster(delay, _ZOO_PARAMS[delay],
                           sigma=1.0 if delay == "bandwidth_coupled" else 5.0)
        ok, _ = executor.scan_supported(method, cluster)
        assert ("scan" if ok else "event") == want, (protocol, delay)
        session = api.Session(small_problem, method, cluster, num_outer=1,
                              executor="auto")
        assert session.executor == want, (protocol, delay)
        # Sweep eligibility follows the same predicate, except for
        # partial_work: it scans SOLO (per-chunk carries are per-run state)
        # but never batches into shared sweep cells.
        if protocol == "partial_work":
            swept, why = api.sweep_supported(method, cluster)
            assert not swept and "sweep" in why
        else:
            assert api.sweep_supported(method, cluster)[0] == ok


def test_eligibility_matrix_shard_routing():
    """resolve_shard: exactly which (protocol, shard, device-count) cells
    produce a sharded plan, which degrade to 'none', and which refuse."""
    lockstep = sorted(executor.LOCKSTEP_PROTOCOLS)
    for protocol in lockstep + ["lag"]:
        # One device: every request degrades to the unsharded path...
        for shard in ("auto", "none", "cells"):
            plan = api.resolve_shard(shard, protocol=protocol, num_workers=K,
                                     n_devices=1)
            assert plan == api.ShardPlan("none", 1), (protocol, shard)
        # ... and with 4 devices, auto/cells shard the cell axis.
        for shard in ("auto", "cells"):
            plan = api.resolve_shard(shard, protocol=protocol, num_workers=K,
                                     n_devices=4)
            assert plan == api.ShardPlan("cells", 4), (protocol, shard)
        assert api.resolve_shard("none", protocol=protocol, num_workers=K,
                                 n_devices=4) == api.ShardPlan("none", 1)
    # Worker sharding: lockstep only, largest pow2 divisor of K that fits.
    for protocol in lockstep:
        assert api.resolve_shard("workers", protocol=protocol, num_workers=4,
                                 n_devices=4) == api.ShardPlan("workers", 4)
        assert api.resolve_shard("workers", protocol=protocol, num_workers=6,
                                 n_devices=4) == api.ShardPlan("workers", 2)
        assert api.resolve_shard("workers", protocol=protocol, num_workers=5,
                                 n_devices=4) == api.ShardPlan("none", 1)
        assert api.resolve_shard("workers", protocol=protocol, num_workers=4,
                                 n_devices=1) == api.ShardPlan("none", 1)
    with pytest.raises(ValueError, match="workers"):
        api.resolve_shard("workers", protocol="lag", num_workers=K,
                          n_devices=4)
    with pytest.raises(ValueError, match="unknown shard"):
        api.resolve_shard("mesh", protocol="sync", num_workers=K)
    # Non-pow2 device counts shard over the largest pow2 subset.
    assert api.resolve_shard("cells", protocol="sync", num_workers=K,
                             n_devices=6) == api.ShardPlan("cells", 4)


def test_shard_auto_degrades_to_none_on_one_device(small_problem):
    """This test process has one CPU device: shard='auto' (and 'cells') must
    produce exactly the shard='none' results -- the 1-device fallback of the
    acceptance contract."""
    m = baselines.cocoa_plus(K, H=16)
    kw = dict(num_outer=3, seeds=(0, 1), eval_every=2, batch="map")
    none = api.run_sweep(small_problem, m, _cluster(), shard="none", **kw)
    for shard in ("auto", "cells"):
        got = api.run_sweep(small_problem, m, _cluster(), shard=shard, **kw)
        for a, b in zip(got, none):
            _assert_result_identical(a.result, b.result)


# ---------------------------------------------------------------------------
# Spec-level threading.
# ---------------------------------------------------------------------------


def test_spec_shard_field_round_trips():
    spec = api.build_preset("zoo-constant", quick=True)
    assert spec.shard == "auto"
    forced = dataclasses.replace(spec, shard="cells")
    assert api.ExperimentSpec.from_json(forced.to_json()) == forced
    d = spec.to_dict()
    del d["shard"]  # old spec JSONs keep their meaning
    assert api.ExperimentSpec.from_dict(d).shard == "auto"


def test_sweep_spec_lag_entry_with_delay_axis(small_problem):
    spec = api.build_preset("zoo-constant", quick=True)
    variants = api.sweep_spec(spec, "ACPD-LAG", seeds=(0, 1),
                              delays=SWEEP_DELAYS, batch="map")
    assert len(variants) == 6
    assert {v.delay for v in variants} == {n for n, _ in SWEEP_DELAYS}
    for v in variants:
        assert v.result.records[-1].gap < v.result.records[0].gap


def test_sweep_spec_threads_spec_shard(small_problem, monkeypatch):
    """sweep_spec forwards the spec's shard field to run_sweep."""
    spec = dataclasses.replace(api.build_preset("zoo-constant", quick=True),
                               shard="none")
    seen = {}
    real = api.sweep.run_sweep

    def spy(*a, **kw):
        seen["shard"] = kw["shard"]
        return real(*a, **kw)

    monkeypatch.setattr(api.sweep, "run_sweep", spy)
    api.sweep_spec(spec, "CoCoA+", batch="map")
    assert seen["shard"] == "none"
    api.sweep_spec(spec, "CoCoA+", batch="map", shard="auto")
    assert seen["shard"] == "auto"


# ---------------------------------------------------------------------------
# The sharded path, end to end (4 fake host devices in a subprocess).
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import numpy as np
    import jax
    from repro import api
    from repro.core import baselines
    from repro.core.simulate import ClusterModel

    K, D = 4, 256
    prob = api.ProblemSpec("rcv1_like",
                           {"K": K, "d": D, "n_per_worker": 32}).build()
    cl = ClusterModel(num_workers=K, straggler_sigma=5.0)
    delays = (("constant", {}), ("shifted_exponential", {"tail_mean": 1.0}),
              ("pareto", {"shape": 1.8, "scale": 0.5}))
    out = {"n_devices": len(jax.devices())}

    def identical(a, b):
        return all((np.asarray(va.result.w) == np.asarray(vb.result.w)).all()
                   and [r.gap for r in va.result.records]
                   == [r.gap for r in vb.result.records]
                   and [r.sim_time for r in va.result.records]
                   == [r.sim_time for r in vb.result.records]
                   for va, vb in zip(a, b))

    m = baselines.cocoa_plus(K, H=16)
    kw = dict(num_outer=3, seeds=(0, 1, 2), gammas=(1.0, 0.5), eval_every=2)
    none = api.run_sweep(prob, m, cl, batch="map", shard="none", **kw)
    cells = api.run_sweep(prob, m, cl, batch="map", shard="cells", **kw)
    auto = api.run_sweep(prob, m, cl, batch="map", shard="auto", **kw)
    out["lockstep_cells_identical"] = identical(none, cells)
    out["lockstep_auto_identical"] = identical(none, auto)
    out["auto_plan"] = list(api.resolve_shard(
        "auto", protocol="sync", num_workers=K).__dict__.values())

    workers = api.run_sweep(prob, m, cl, batch="map", shard="workers", **kw)
    out["workers_allclose"] = all(
        np.allclose(np.asarray(va.result.w), np.asarray(vb.result.w),
                    rtol=1e-5, atol=1e-6)
        for va, vb in zip(none, workers))

    mlag = baselines.acpd_lag(K, D, B=2, T=4, rho_d=32, gamma=0.5, H=16)
    lkw = dict(num_outer=1, seeds=(0, 1, 2, 3), delays=delays, eval_every=2)
    lnone = api.run_sweep(prob, mlag, cl, batch="map", shard="none", **lkw)
    lcells = api.run_sweep(prob, mlag, cl, batch="map", shard="cells", **lkw)
    out["lag_cells_identical"] = identical(lnone, lcells)
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def shard_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_sharded_cells_bit_identical_on_four_devices(shard_subprocess):
    """The mesh acceptance contract: with 4 host devices, shard='cells'
    (and 'auto', which resolves to it) reproduces the unsharded sweep
    bit-for-bit for lockstep AND lag grids."""
    out = shard_subprocess
    assert out["n_devices"] == 4
    assert out["auto_plan"] == ["cells", 4]
    assert out["lockstep_cells_identical"]
    assert out["lockstep_auto_identical"]
    assert out["lag_cells_identical"]


def test_sharded_workers_allclose_on_four_devices(shard_subprocess):
    """shard='workers' re-associates the per-round aggregate (one psum per
    round): deterministic and numerically equal, not bit-equal."""
    assert shard_subprocess["workers_allclose"]
