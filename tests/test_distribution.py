"""Distribution integration: a miniature multi-device dry-run in a subprocess
(8 fake host devices, 2x4 mesh), proving lower+compile+collectives end to end
without touching this process's 1-device jax state.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.configs import InputShape, get_config
    from repro.core import exchange as exch_lib
    from repro.launch.steps import (TrainSetup, build_prefill_step,
                                    build_serve_step, build_train_step)
    from repro.launch import hlo_analysis
    from repro.optim.optimizers import OptimizerConfig

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    out = {}

    shape = InputShape("mini_train", 64, 8, "train")
    exch = exch_lib.ExchangeConfig(num_groups=2, group_size=1, sync_period=4,
                                   rho=0.05)
    setup = TrainSetup(cfg=cfg, optimizer=OptimizerConfig(), exchange=exch)
    jitted, _, abstract = build_train_step(setup, mesh, shape)
    with mesh:
        compiled = jitted.lower(*abstract).compile()
    r = hlo_analysis.analyze(compiled)
    out["train"] = {"colls": r.collectives["counts"],
                    "flops": r.flops_per_device}

    shape = InputShape("mini_decode", 128, 8, "decode")
    jitted, _, abstract = build_serve_step(cfg, mesh, shape)
    with mesh:
        compiled = jitted.lower(*abstract).compile()
    out["decode"] = {"colls": hlo_analysis.parse_collectives(
        compiled.as_text()).counts}

    shape = InputShape("mini_prefill", 128, 8, "prefill")
    jitted, _, abstract = build_prefill_step(cfg, mesh, shape)
    with mesh:
        compiled = jitted.lower(*abstract).compile()
    out["prefill"] = {"ok": True}
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def mini_dryrun():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_train_step_lowers_with_collectives(mini_dryrun):
    colls = mini_dryrun["train"]["colls"]
    assert sum(colls.values()) > 0  # model+data parallel must communicate
    assert mini_dryrun["train"]["flops"] > 0


def test_decode_step_lowers(mini_dryrun):
    assert "decode" in mini_dryrun


def test_prefill_step_lowers(mini_dryrun):
    assert mini_dryrun["prefill"]["ok"]
