"""Local SDCA solver: coordinate optimality, subproblem ascent, convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import objectives as obj
from repro.core.sdca import (sdca_reference, solve_subproblem,
                             solve_subproblem_indices)


def _subproblem_value(loss, dalpha, w_eff, alpha, X, y, lam, n, sigma_p):
    """G_k^{sigma'} of Eq. 8 (up to dalpha-independent constants)."""
    v = X.T @ dalpha / (lam * n)
    a = alpha + dalpha
    return (float(np.sum(np.asarray(obj.neg_conj(loss, jnp.asarray(a), jnp.asarray(y))))) / n
            - float(w_eff @ (X.T @ dalpha)) / n
            - 0.5 * lam * sigma_p * float(v @ v))


@pytest.mark.parametrize("loss", ["ridge", "smoothed_hinge", "logistic"])
def test_coordinate_step_is_ascent(loss):
    """Each SDCA step must not decrease the local subproblem objective."""
    rng = np.random.default_rng(3)
    n_k, d = 32, 64
    X = rng.standard_normal((n_k, d)).astype(np.float32) / np.sqrt(d)
    y = np.sign(rng.standard_normal(n_k)).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32) * 0.1
    norms = np.sum(X * X, axis=1)
    lam, n, sp = 1e-2, 128, 2.0

    prev = _subproblem_value(loss, np.zeros(n_k, np.float32), w,
                             np.zeros(n_k, np.float32), X, y, lam, n, sp)
    for h in range(1, 20):
        idx = jnp.asarray(rng.integers(0, n_k, h).astype(np.int32))
        # re-run from scratch with a prefix of the same visit order
        res = solve_subproblem_indices(
            jnp.asarray(w), jnp.zeros(n_k), jnp.asarray(X), jnp.asarray(y),
            jnp.asarray(norms), lam, n, sp, idx, loss=loss)
        val = _subproblem_value(loss, np.asarray(res.delta_alpha), w,
                                np.zeros(n_k, np.float32), X, y, lam, n, sp)
        assert val >= prev - 1e-5 or h == 1


def test_v_matches_dalpha():
    """v must equal (1/lam n) A_k^T dalpha exactly (Alg. 2 line 6)."""
    rng = np.random.default_rng(4)
    n_k, d = 48, 96
    X = jnp.asarray(rng.standard_normal((n_k, d)).astype(np.float32)) * 0.2
    y = jnp.asarray(np.sign(rng.standard_normal(n_k)).astype(np.float32))
    norms = jnp.sum(X * X, axis=1)
    lam, n, sp = 1e-3, 192, 1.0
    res = solve_subproblem(jnp.zeros(d), jnp.zeros(n_k), X, y, norms, lam, n,
                           sp, jax.random.key(0), loss="ridge", num_steps=100)
    v_expect = X.T @ res.delta_alpha / (lam * n)
    np.testing.assert_allclose(np.asarray(res.v), np.asarray(v_expect),
                               rtol=1e-5, atol=1e-6)


def test_single_machine_sdca_converges(small_problem, oracle):
    _, w_star = oracle
    alpha, w = sdca_reference(small_problem.global_X(),
                              small_problem.global_y(), small_problem.lam,
                              jax.random.key(1), loss="ridge", num_epochs=40)
    gap = obj.duality_gap(alpha.reshape(small_problem.y.shape),
                          small_problem.X, small_problem.y,
                          small_problem.lam, loss="ridge")
    assert float(gap) < 1e-5
    np.testing.assert_allclose(np.asarray(w), w_star, rtol=5e-3, atol=5e-4)


@pytest.mark.parametrize("loss", ["smoothed_hinge", "logistic"])
def test_classification_losses_converge(loss, small_problem):
    alpha, w = sdca_reference(small_problem.global_X(),
                              small_problem.global_y(), small_problem.lam,
                              jax.random.key(2), loss=loss, num_epochs=40)
    gap = obj.duality_gap(alpha.reshape(small_problem.y.shape),
                          small_problem.X, small_problem.y,
                          small_problem.lam, loss=loss)
    assert float(gap) < 1e-3
    # trained predictor should beat chance comfortably
    margin = np.asarray(small_problem.global_X() @ w) * np.asarray(
        small_problem.global_y())
    assert (margin > 0).mean() > 0.8
