"""End-to-end behaviour tests for the paper's system: the full ACPD stack
(straggler clock -> group-wise server -> SDCA workers -> top-k filter) run as
a user would run it, checked against the paper's own narrative.
"""

import numpy as np

from repro.core import baselines
from repro.core.acpd import run_method
from repro.core.simulate import ClusterModel
from repro.data.synthetic import LinearDatasetSpec, make_linear_problem


def test_paper_pipeline_end_to_end(small_problem):
    """One full experiment: ACPD on 4 workers with a sigma=5 straggler reaches
    gap 1e-3 in less simulated time AND fewer bytes than CoCoA+."""
    cluster = ClusterModel(num_workers=4, straggler_sigma=5.0)
    acpd = run_method(small_problem,
                      baselines.acpd(4, small_problem.d, B=2, T=10, rho_d=64,
                                     gamma=0.5, H=384),
                      cluster, num_outer=8, eval_every=2, seed=0)
    cocoa = run_method(small_problem, baselines.cocoa_plus(4, H=384), cluster,
                       num_outer=80, eval_every=2, seed=0)
    target = 1e-3
    t_a, t_c = acpd.time_to_gap(target), cocoa.time_to_gap(target)
    assert t_a is not None and t_c is not None and t_a < t_c
    ra = next(r for r in acpd.records if r.gap <= target)
    rc = next(r for r in cocoa.records if r.gap <= target)
    # Table I: O(rho d) vs O(d) in the upload direction. With the ring
    # allreduce split evenly into up/down (like-for-like accounting), the
    # honest upload ceiling at rho=64/512=12.5% is ~2.4x, and the total only
    # narrowly favors ACPD (its catch-up replies are dense); the >40x ratios
    # show up at RCV1+ dimensionality (bench_table1 static rows).
    assert ra.bytes_up < rc.bytes_up / 2
    assert ra.bytes_up + ra.bytes_down < rc.bytes_up + rc.bytes_down


def test_practical_filter_variant_converges_like_paper_claims():
    """Sec. III-B2: replacing the exact dual put-back with the primal residual
    'does not affect the convergence empirically' -- verify with tight rho."""
    prob = make_linear_problem(
        LinearDatasetSpec(num_workers=4, n_per_worker=96, d=1024,
                          nnz_per_row=16, seed=21), lam=1e-3)
    res = run_method(prob,
                     baselines.acpd(4, 1024, B=2, T=10, rho_d=16, gamma=0.5,
                                    H=256),
                     ClusterModel(num_workers=4), num_outer=10, eval_every=5,
                     seed=1)
    gaps = [r.gap for r in res.records]
    assert gaps[-1] < 1e-3
    # primal-dual certified gap and server-model gap agree at convergence
    assert abs(res.records[-1].gap_server - res.records[-1].gap) < 5e-3


def test_rho_robustness_figure_4a():
    """Fig. 4a: convergence is stable across two orders of magnitude of rho*d
    while the gap is above ~1e-4."""
    prob = make_linear_problem(
        LinearDatasetSpec(num_workers=4, n_per_worker=96, d=1024,
                          nnz_per_row=16, seed=22), lam=1e-3)
    finals = {}
    for rho_d in (16, 64, 1024):
        res = run_method(prob,
                         baselines.acpd(4, 1024, B=2, T=10, rho_d=rho_d,
                                        gamma=0.5, H=256),
                         ClusterModel(num_workers=4), num_outer=8,
                         eval_every=8, seed=2)
        finals[rho_d] = res.records[-1].gap
    assert all(g < 2e-3 for g in finals.values()), finals
