"""Use hypothesis when installed; otherwise a deterministic fallback.

The property tests in this suite only use ``@settings(...) @given(st.integers(a, b), ...)``.
When ``hypothesis`` is unavailable (it is not baked into every container this
repo runs in), ``given`` degrades to a deterministic sweep: the endpoints of
every integer strategy plus a fixed-seed random sample, capped at the test's
``max_examples``. That keeps the properties exercised (including the edge
cases hypothesis shrinks toward) instead of skipping four whole modules.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""

from __future__ import annotations

import itertools

try:  # pragma: no cover - exercised implicitly by which branch imports
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 20

    class _IntegersStrategy:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = int(lo), int(hi)

        def examples(self, rng: np.random.Generator, n: int) -> list[int]:
            edge = [self.lo, self.hi, min(self.hi, self.lo + 1)]
            rand = rng.integers(self.lo, self.hi + 1, size=max(n, 1)).tolist()
            return [int(v) for v in itertools.chain(edge, rand)][:n]

    class _FloatsStrategy:
        def __init__(self, lo: float, hi: float):
            self.lo, self.hi = float(lo), float(hi)

        def examples(self, rng: np.random.Generator, n: int) -> list[float]:
            edge = [self.lo, self.hi, 0.5 * (self.lo + self.hi)]
            rand = rng.uniform(self.lo, self.hi, size=max(n, 1)).tolist()
            return [float(v) for v in itertools.chain(edge, rand)][:n]

    class _SampledFromStrategy:
        def __init__(self, elements):
            self.elements = list(elements)

        def examples(self, rng: np.random.Generator, n: int):
            idx = rng.integers(0, len(self.elements), size=max(n, 1))
            cycled = itertools.chain(self.elements, (self.elements[i] for i in idx))
            return list(cycled)[:n]

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntegersStrategy:
            return _IntegersStrategy(min_value, max_value)

        @staticmethod
        def floats(min_value: float, max_value: float, **_ignored) -> _FloatsStrategy:
            return _FloatsStrategy(min_value, max_value)

        @staticmethod
        def sampled_from(elements) -> _SampledFromStrategy:
            return _SampledFromStrategy(elements)

    st = _Strategies()  # type: ignore[assignment]

    def given(*strategies, **kw_strategies):  # type: ignore[misc]
        # The shim's contract: a property decorated with @given is ALWAYS
        # exercised -- at least one deterministic example -- or the
        # decoration fails loudly.  (An earlier version accepted only
        # positional strategies; keyword-strategy tests then swept zero
        # columns and every case silently passed without running.)
        if not strategies and not kw_strategies:
            raise TypeError("given() requires at least one strategy")
        for s in itertools.chain(strategies, kw_strategies.values()):
            if not hasattr(s, "examples"):
                raise TypeError(
                    f"unsupported strategy {s!r}: the fallback shim only "
                    f"implements st.integers / st.floats / st.sampled_from; "
                    f"install hypothesis for the full strategy language")

        def decorate(fn):
            # No functools.wraps: pytest must see a ZERO-arg signature, or it
            # would try to resolve the property arguments as fixtures.
            def wrapper():
                n = max(1, getattr(wrapper, "_compat_max_examples",
                                   _DEFAULT_MAX_EXAMPLES))
                rng = np.random.default_rng(0)
                pos_cols = [s.examples(rng, n) for s in strategies]
                names = list(kw_strategies)
                kw_cols = [kw_strategies[k].examples(rng, n) for k in names]
                ran = 0
                for case in zip(*(pos_cols + kw_cols)):
                    fn(*case[:len(pos_cols)],
                       **dict(zip(names, case[len(pos_cols):])))
                    ran += 1
                assert ran >= 1, "fallback @given swept zero examples"

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return decorate

    def settings(*, max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):  # type: ignore[misc]
        def decorate(fn):
            fn._compat_max_examples = max(1, max_examples)
            return fn

        return decorate


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
