"""The multi-tenant experiment service (repro.serve) + run_sweep_cells.

Pins the serve contract from ISSUE/ROADMAP open item 1:

* coalesced tenant batches share ONE compiled sweep call (executor.STATS
  trace/dispatch counters + the PR-6-style jit-cache-key mirror agree), and
  each tenant's streamed Round/Sync/Eval/Stop events are bit-identical to a
  solo ``Session`` run;
* registry-name/spec-validation errors surface at enqueue time as typed
  ``SpecValidationError`` with the full known-entry listing (a queued bad
  spec never reaches a batch);
* fairness and backpressure: round-robin across tenants inside a batch (a
  deep backlog cannot starve another tenant) and bounded per-tenant depth
  with a typed ``BackpressureError`` instead of a hang;
* the solo lane (group-family protocols, early-stop specs, non-presampleable
  lag delays) streams real Session events through the same handle API;
* the HTTP front end round-trips submit -> events -> stats.

``run_sweep_cells`` itself (the api-layer substrate the coalescer batches
through) is pinned against ``run_sweep`` and solo sessions at the top.
"""

import dataclasses
import json
import threading
import urllib.request

import numpy as np
import pytest

from repro import api
from repro.api.session import EvalEvent, RoundEvent, StopEvent, SyncEvent
from repro.core import baselines, executor
from repro.core.simulate import ClusterModel
from repro.serve import (
    BackpressureError,
    CoalescePolicy,
    ExperimentService,
    SpecValidationError,
    batch_key,
    form_batch,
    serve_http,
    sweep_cache_key,
)
from repro.serve.coalesce import Request

K, D = 4, 256


def _problem_spec(seed=0):
    return api.ProblemSpec("linear_synthetic",
                           {"num_workers": K, "n_per_worker": 48, "d": D,
                            "nnz_per_row": 12, "seed": seed, "lam": 1e-3})


def _cluster(delay="constant", params=None, sigma=5.0):
    return ClusterModel(num_workers=K, straggler_sigma=sigma,
                        delay_model=delay,
                        delay_params=tuple((params or {}).items()))


def _spec(name="t", method=None, cluster=None, seed=0, num_outer=4,
          eval_every=2, **kw):
    method = method or baselines.cocoa_plus(K, H=8)
    return api.ExperimentSpec(
        name=name, problem=_problem_spec(),
        cluster=cluster or _cluster(),
        methods=(api.MethodEntry(method, num_outer),),
        eval_every=eval_every, seed=seed, **kw)


def _policy(**kw):
    kw.setdefault("batch", "map")
    kw.setdefault("shard", "none")
    kw.setdefault("max_wait_s", 0.0)
    return CoalescePolicy(**kw)


def _solo_events(spec, method_name):
    entry = spec.method_named(method_name)
    sess = api.Session(spec.problem.build(), entry.config, spec.cluster,
                       num_outer=entry.num_outer, seed=spec.seed,
                       eval_every=spec.eval_every, executor="scan")
    events = list(sess.events())
    return events, sess.result()


# ---------------------------------------------------------------------------
# run_sweep_cells: the explicit-cell substrate.
# ---------------------------------------------------------------------------


class TestRunSweepCells:
    def test_matches_cross_product_run_sweep(self):
        prob = _problem_spec().build()
        m = baselines.cocoa_plus(K, H=8)
        cl = _cluster()
        grid = api.run_sweep(prob, m, cl, num_outer=4, seeds=(0, 1),
                             gammas=(0.5, 1.0), batch="map", shard="none")
        # run_sweep's gamma axis keeps the method's own sigma_prime; carry
        # it per cell (sigma_prime=None would re-resolve the protocol
        # default per gamma instead)
        cells = [api.SweepCellSpec(cl, s, g, m.sigma_prime)
                 for s in (0, 1) for g in (0.5, 1.0)]
        explicit = api.run_sweep_cells(prob, m, cells, num_outer=4,
                                       batch="map", shard="none")
        for a, b in zip(grid, explicit):
            assert (a.seed, a.gamma) == (b.seed, b.gamma)
            np.testing.assert_array_equal(a.result.w, b.result.w)
            assert ([r.gap for r in a.result.records]
                    == [r.gap for r in b.result.records])
            assert b.rounds is not None and len(b.rounds) == 4

    def test_heterogeneous_clusters_one_call(self):
        """Cells of DIFFERENT delay models batch into one dispatch; the
        trajectory is shared, the accounting is per-cell."""
        prob = _problem_spec().build()
        m = baselines.cocoa_plus(K, H=8)
        cells = [api.SweepCellSpec(_cluster(), 0, 1.0),
                 api.SweepCellSpec(_cluster("pareto",
                                            {"shape": 1.8, "scale": 0.5}),
                                   0, 1.0)]
        calls = executor.STATS["sweep_calls"]
        out = api.run_sweep_cells(prob, m, cells, num_outer=4, batch="map",
                                  shard="none")
        assert executor.STATS["sweep_calls"] == calls + 1
        np.testing.assert_array_equal(out[0].result.w, out[1].result.w)
        assert out[0].delay == "constant" and out[1].delay == "pareto"
        assert out[0].rounds[0].sim_time != out[1].rounds[0].sim_time

    def test_lag_cells_match_run_sweep(self):
        prob = _problem_spec().build()
        m = baselines.acpd_lag(K, D, B=2, T=2, rho_d=32, gamma=0.5, H=8)
        cl = _cluster("pareto", {"shape": 1.8, "scale": 0.5})
        grid = api.run_sweep(prob, m, cl, num_outer=2, seeds=(0, 3),
                             batch="map", shard="none")
        explicit = api.run_sweep_cells(
            prob, m, [api.SweepCellSpec(cl, 0), api.SweepCellSpec(cl, 3)],
            num_outer=2, batch="map", shard="none")
        for a, b in zip(grid, explicit):
            np.testing.assert_array_equal(a.result.w, b.result.w)
            assert ([r.sim_time for r in a.result.records]
                    == [r.sim_time for r in b.result.records])
            assert b.rounds is not None

    def test_rejects_wrong_worker_count(self):
        prob = _problem_spec().build()
        m = baselines.cocoa_plus(K, H=8)
        with pytest.raises(ValueError, match="num_workers=8"):
            api.run_sweep_cells(
                prob, m, [api.SweepCellSpec(ClusterModel(num_workers=8), 0)],
                num_outer=2)

    def test_rejects_group_protocols_and_empty(self):
        prob = _problem_spec().build()
        with pytest.raises(ValueError, match="scan-capable"):
            api.run_sweep_cells(prob, baselines.acpd(K, D),
                                [api.SweepCellSpec(_cluster(), 0)],
                                num_outer=2)
        with pytest.raises(ValueError, match="empty"):
            api.run_sweep_cells(prob, baselines.cocoa_plus(K, H=8), [],
                                num_outer=2)


# ---------------------------------------------------------------------------
# Admission: validation + backpressure (satellite 1 + 3).
# ---------------------------------------------------------------------------


class TestAdmission:
    def test_unknown_problem_rejected_at_enqueue(self):
        svc = ExperimentService(_policy())
        spec = _spec()
        bad = dataclasses.replace(
            spec, problem=dataclasses.replace(spec.problem, kind="nope"))
        with pytest.raises(SpecValidationError, match="linear_synthetic"):
            svc.submit("a", bad)
        # the bad spec never reached any queue
        assert svc.stats()["pending_batched"] == 0
        assert svc.counters["rejected_validation"] == 1

    def test_unknown_registry_names_list_entries(self):
        svc = ExperimentService(_policy())
        spec = _spec(method=dataclasses.replace(
            baselines.cocoa_plus(K, H=8), compressor="zstd"))
        with pytest.raises(SpecValidationError, match="topk_q8"):
            svc.submit("a", spec)
        spec = _spec(cluster=_cluster().__class__(num_workers=K,
                                                  delay_model="wat"))
        with pytest.raises(SpecValidationError, match="pareto"):
            svc.submit("a", spec)

    def test_unknown_method_selector(self):
        svc = ExperimentService(_policy())
        with pytest.raises(SpecValidationError, match="no method named"):
            svc.submit("a", _spec(), method="nope")

    def test_multi_method_spec_needs_selector(self):
        spec = _spec()
        multi = dataclasses.replace(
            spec, methods=spec.methods + (api.MethodEntry(
                baselines.cocoa_v1(K, H=8), 4),))
        svc = ExperimentService(_policy())
        with pytest.raises(SpecValidationError, match="method=<name>"):
            svc.submit("a", multi)
        h = svc.submit("a", multi, method="CoCoA+")
        svc.drain()
        assert h.result().method.name == "CoCoA+"

    def test_validate_catches_structural_errors(self):
        spec = _spec()
        bad_b = dataclasses.replace(
            spec, methods=(api.MethodEntry(dataclasses.replace(
                spec.methods[0].config, B=99), 4),))
        with pytest.raises(ValueError, match="B=99"):
            bad_b.validate()
        with pytest.raises(ValueError, match="eval_every"):
            dataclasses.replace(spec, eval_every=0).validate()

    def test_backpressure_typed_rejection_not_hang(self):
        svc = ExperimentService(_policy(max_tenant_depth=2))
        svc.submit("a", _spec())
        svc.submit("a", _spec())
        with pytest.raises(BackpressureError, match="max_tenant_depth=2"):
            svc.submit("a", _spec())
        # another tenant is unaffected
        svc.submit("b", _spec())
        assert svc.counters["rejected_backpressure"] == 1
        svc.drain()
        # depth frees up after completion
        h = svc.submit("a", _spec())
        svc.drain()
        assert h.done()


# ---------------------------------------------------------------------------
# Coalescing correctness: shared compile + bit-identical streams.
# ---------------------------------------------------------------------------


class TestCoalescing:
    def test_two_tenants_share_one_dispatch_and_compile(self):
        """The acceptance-criteria contract: compatible tenant requests run
        as ONE sweep call mapping to ONE jit cache key, and each stream is
        bit-identical to the tenant's solo Session run."""
        svc = ExperimentService(_policy())
        m = baselines.cocoa_plus(K, H=8)
        sa = _spec("alice-exp", method=m)
        sb = _spec("bob-exp", method=dataclasses.replace(m, gamma=0.5),
                   cluster=_cluster("shifted_exponential",
                                    {"tail_mean": 1.0}))
        calls, traces = (executor.STATS["sweep_calls"],
                         executor.STATS["sweep_traces"])
        ha = svc.submit("alice", sa)
        hb = svc.submit("bob", sb)
        svc.drain()
        assert executor.STATS["sweep_calls"] == calls + 1  # ONE dispatch
        assert svc.counters["batches"] == 1
        assert svc.counters["batched_requests"] == 2
        assert svc.stats()["coalesce_factor"] == 2.0

        # identical jit cache key for both requests (PR-6 contract style)
        prob = svc._problem_for(sa)
        plan = api.resolve_shard("none", protocol=m.protocol, num_workers=K)
        keys = [sweep_cache_key(prob, s.methods[0].config, 2, num_outer=4,
                                eval_every=2, batch="map", plan=plan)
                for s in (sa, sb)]
        assert keys[0] == keys[1]

        for spec, handle in ((sa, ha), (sb, hb)):
            solo_events, solo_result = _solo_events(spec, m.name)
            served = list(handle.events())
            assert served == solo_events  # bit-identical, same order/types
            np.testing.assert_array_equal(handle.result().w, solo_result.w)
        # the second identical batch shape is a warm-cache hit
        assert executor.STATS["sweep_traces"] >= traces

    def test_warm_cache_hit_on_repeat_batch_shape(self):
        svc = ExperimentService(_policy())
        for _ in range(2):
            svc.submit("a", _spec())
            svc.submit("b", _spec(seed=0, cluster=_cluster(sigma=2.0)))
            svc.drain()
        cs = svc.compile_cache.stats()
        assert cs == {"entries": 1, "hits": 1, "misses": 1, "hit_rate": 0.5}

    def test_cache_mirror_agrees_with_jit_traces(self):
        """The CompileCache key mirror is honest: a mirrored hit means jit
        did NOT retrace (STATS sweep_traces unchanged)."""
        svc = ExperimentService(_policy())
        svc.submit("a", _spec())
        svc.submit("b", _spec(cluster=_cluster(sigma=2.0)))
        svc.drain()
        traces = executor.STATS["sweep_traces"]
        svc.submit("a", _spec(seed=5))
        svc.submit("b", _spec(seed=6))
        svc.drain()
        assert svc.compile_cache.hits == 1
        assert executor.STATS["sweep_traces"] == traces  # no retrace

    def test_incompatible_keys_do_not_coalesce(self):
        svc = ExperimentService(_policy())
        svc.submit("a", _spec(num_outer=4))
        svc.submit("b", _spec(num_outer=6))  # different round budget
        svc.drain()
        assert svc.counters["batches"] == 2
        assert svc.stats()["coalesce_factor"] == 1.0

    def test_lag_tenants_coalesce_across_delay_models(self):
        m = baselines.acpd_lag(K, D, B=2, T=2, rho_d=32, gamma=0.5, H=8)
        sa = _spec("a", method=m,
                   cluster=_cluster("pareto", {"shape": 1.8, "scale": 0.5}))
        sb = _spec("b", method=m,
                   cluster=_cluster("shifted_exponential",
                                    {"tail_mean": 1.0}))
        svc = ExperimentService(_policy())
        lag_calls = executor.STATS["sweep_lag_calls"]
        ha = svc.submit("a", sa)
        hb = svc.submit("b", sb)
        svc.drain()
        assert executor.STATS["sweep_lag_calls"] == lag_calls + 1
        for spec, handle in ((sa, ha), (sb, hb)):
            solo_events, solo_result = _solo_events(spec, m.name)
            assert list(handle.events()) == solo_events
            np.testing.assert_array_equal(handle.result().w, solo_result.w)

    def test_solo_lane_group_protocol_and_early_stop(self):
        svc = ExperimentService(_policy())
        hg = svc.submit("a", _spec(method=baselines.acpd(K, D)))  # group
        hs = svc.submit("a", _spec(target_gap=1e-12))  # early stop
        svc.drain()
        assert svc.counters["solo_requests"] == 2
        assert svc.counters["batches"] == 0
        events = list(hg.events())
        assert isinstance(events[-1], StopEvent)
        assert hs.result().records  # ran, streamed, finished

    def test_failed_batch_raises_not_hangs(self):
        svc = ExperimentService(_policy())
        h = svc.submit("a", _spec())

        def boom(*a, **k):
            raise RuntimeError("synthetic executor failure")

        import repro.serve.service as service_mod
        orig = service_mod.run_sweep_cells
        service_mod.run_sweep_cells = boom
        try:
            svc.drain()
        finally:
            service_mod.run_sweep_cells = orig
        with pytest.raises(RuntimeError, match="synthetic"):
            h.result(timeout=1.0)
        assert svc.counters["failed"] == 1
        # tenant depth was released: a new submit is admitted
        svc.submit("a", _spec())
        svc.drain()


# ---------------------------------------------------------------------------
# Fairness.
# ---------------------------------------------------------------------------


class TestFairness:
    def test_round_robin_across_tenants(self):
        """A tenant with a deep backlog cannot starve another: with
        max_batch=4 and queues slow=[6 reqs] fast=[1 req], the closing batch
        interleaves tenants instead of draining `slow` first."""
        reqs = []
        spec = _spec()
        for i in range(6):
            reqs.append(Request("slow", spec, spec.methods[0], None, i))
        reqs.append(Request("fast", spec, spec.methods[0], None, 6))
        picked = form_batch(reqs, max_batch=4)
        # fast's single request made it into the first batch of 4
        assert [r.tenant for r in picked].count("fast") == 1
        # oldest-first within each tenant
        slow_orders = [r.order for r in picked if r.tenant == "slow"]
        assert slow_orders == sorted(slow_orders) == [0, 1, 2]

    def test_fast_tenant_not_starved_end_to_end(self):
        svc = ExperimentService(_policy(max_batch=2, max_tenant_depth=8))
        slow = [svc.submit("slow", _spec(seed=i)) for i in range(4)]
        fast = svc.submit("fast", _spec(seed=9))
        # first dispatched batch (max_batch=2) must contain fast's request
        svc._dispatch_once(flush=True)
        assert fast.done()
        assert sum(h.done() for h in slow) == 1  # one slot went to slow
        svc.drain()
        assert all(h.done() for h in slow)

    def test_batch_key_groups_what_should_group(self):
        pol = _policy()
        spec_a = _spec("a")
        spec_b = _spec("b", cluster=_cluster("pareto",
                                             {"shape": 1.8, "scale": 0.5}),
                       seed=3)
        gamma_var = _spec("c", method=dataclasses.replace(
            baselines.cocoa_plus(K, H=8), gamma=0.25, name="other"))
        assert (batch_key(spec_a, spec_a.methods[0], policy=pol)
                == batch_key(spec_b, spec_b.methods[0], policy=pol)
                == batch_key(gamma_var, gamma_var.methods[0], policy=pol))
        h_var = _spec("d", method=baselines.cocoa_plus(K, H=16))
        assert (batch_key(spec_a, spec_a.methods[0], policy=pol)
                != batch_key(h_var, h_var.methods[0], policy=pol))


# ---------------------------------------------------------------------------
# Streams + HTTP front end.
# ---------------------------------------------------------------------------


class TestStreamsAndHttp:
    def test_event_stream_types_and_order(self):
        svc = ExperimentService(_policy())
        h = svc.submit("a", _spec())
        svc.drain()
        events = list(h.events(timeout=5.0))
        kinds = [type(e) for e in events]
        assert kinds[0] is RoundEvent
        assert kinds[-1] is StopEvent
        assert SyncEvent in kinds and EvalEvent in kinds
        # deferred-eval contract: evals arrive after the last round event
        last_round = max(i for i, k in enumerate(kinds) if k is RoundEvent)
        first_eval = min(i for i, k in enumerate(kinds) if k is EvalEvent)
        assert first_eval > last_round

    def test_dispatcher_thread_end_to_end(self):
        svc = ExperimentService(CoalescePolicy(
            max_batch=8, max_wait_s=0.02, max_tenant_depth=8,
            batch="map", shard="none")).start()
        try:
            ha = svc.submit("alice", _spec())
            hb = svc.submit("bob", _spec(seed=1))
            ra, rb = ha.result(timeout=120), hb.result(timeout=120)
            assert ra.records and rb.records
            assert svc.counters["batches"] >= 1
        finally:
            svc.stop()

    def test_http_round_trip(self):
        svc = ExperimentService(_policy()).start()
        server = serve_http(svc, "127.0.0.1", 0)
        port = server.server_address[1]
        t = threading.Thread(target=server.serve_forever, daemon=True)
        t.start()
        base = f"http://127.0.0.1:{port}"
        try:
            body = json.dumps({"tenant": "alice",
                               "spec": _spec().to_dict()}).encode()
            req = urllib.request.Request(f"{base}/submit", data=body,
                                         method="POST")
            with urllib.request.urlopen(req, timeout=30) as r:
                job = json.loads(r.read())
            assert job["tenant"] == "alice"
            with urllib.request.urlopen(f"{base}/events/{job['job_id']}",
                                        timeout=120) as r:
                payload = json.loads(r.read())
            kinds = [e["type"] for e in payload["events"]]
            assert kinds[0] == "round" and kinds[-1] == "stop"
            assert "eval" in kinds
            with urllib.request.urlopen(f"{base}/stats", timeout=30) as r:
                stats = json.loads(r.read())
            assert stats["submitted"] >= 1
            assert "compile_cache" in stats and "devices" in stats
        finally:
            server.shutdown()
            svc.stop()

    def test_http_rejects_bad_spec_with_listing(self):
        svc = ExperimentService(_policy()).start()
        server = serve_http(svc, "127.0.0.1", 0)
        port = server.server_address[1]
        threading.Thread(target=server.serve_forever, daemon=True).start()
        try:
            spec = _spec().to_dict()
            spec["problem"]["kind"] = "nope"
            body = json.dumps({"tenant": "a", "spec": spec}).encode()
            req = urllib.request.Request(
                f"http://127.0.0.1:{port}/submit", data=body, method="POST")
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req, timeout=30)
            assert ei.value.code == 400
            err = json.loads(ei.value.read())["error"]
            assert "linear_synthetic" in err  # full known-entry listing
        finally:
            server.shutdown()
            svc.stop()
