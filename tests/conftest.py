import numpy as np
import pytest

import jax

# Tests run on the host CPU (1 device). The multi-device dry-run tests spawn
# subprocesses with their own XLA_FLAGS -- never set the flag here.
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def small_problem():
    from repro.data.synthetic import LinearDatasetSpec, make_linear_problem

    spec = LinearDatasetSpec(num_workers=4, n_per_worker=128, d=512,
                             nnz_per_row=24, seed=7)
    return make_linear_problem(spec, lam=1e-3, loss="ridge")


@pytest.fixture(scope="session")
def oracle(small_problem):
    """Near-exact optimum of the small problem via long single-machine SDCA."""
    from repro.core.sdca import sdca_reference

    alpha, w = sdca_reference(
        small_problem.global_X(), small_problem.global_y(), small_problem.lam,
        jax.random.key(0), loss="ridge", num_epochs=60)
    return np.asarray(alpha), np.asarray(w)
