"""Objectives: conjugacy, duality gap, primal-dual map (paper Eqs. 2-5)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import objectives as obj


LOSSES = ["ridge", "smoothed_hinge", "logistic"]


@pytest.mark.parametrize("loss", LOSSES)
def test_fenchel_young_inequality(loss):
    """phi(z) + phi*(-alpha) >= -alpha*z for feasible alpha (conjugacy)."""
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal(256).astype(np.float32) * 2)
    y = jnp.asarray(np.sign(rng.standard_normal(256)).astype(np.float32))
    a = jnp.asarray(rng.uniform(0.05, 0.95, 256).astype(np.float32)) * y
    lhs = obj.phi(loss, z, y) - obj.neg_conj(loss, a, y)
    rhs = -a * z
    assert bool(jnp.all(lhs >= rhs - 1e-5))


@pytest.mark.parametrize("loss", LOSSES)
def test_fenchel_young_equality_at_gradient(loss):
    """Equality holds at -u in d phi(z): phi(z) + phi*(-u) == -u z."""
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.standard_normal(128).astype(np.float32))
    y = jnp.asarray(np.sign(rng.standard_normal(128)).astype(np.float32))
    u = obj.dual_feasible_direction(loss, z, y)
    lhs = obj.phi(loss, z, y) - obj.neg_conj(loss, u, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(-u * z),
                               rtol=1e-4, atol=1e-4)


def test_duality_gap_nonnegative(small_problem):
    rng = np.random.default_rng(2)
    K, n_k = small_problem.y.shape
    alpha = jnp.asarray(rng.uniform(-0.5, 0.5, (K, n_k)).astype(np.float32))
    alpha = alpha * small_problem.y  # keep y*alpha >= -0.5 (ridge: any fine)
    g = obj.duality_gap(alpha, small_problem.X, small_problem.y,
                        small_problem.lam, loss="ridge")
    assert float(g) >= -1e-6


def test_gap_zero_at_optimum(small_problem, oracle):
    alpha, w = oracle
    K, n_k = small_problem.y.shape
    g = obj.duality_gap(jnp.asarray(alpha.reshape(K, n_k)), small_problem.X,
                        small_problem.y, small_problem.lam, loss="ridge")
    assert float(g) < 1e-6


def test_primal_dual_map(small_problem, oracle):
    """w(alpha*) from Eq. 5 equals the SDCA-maintained w."""
    alpha, w = oracle
    K, n_k = small_problem.y.shape
    w_alpha = obj.primal_from_dual(jnp.asarray(alpha.reshape(K, n_k)),
                                   small_problem.X, small_problem.lam)
    np.testing.assert_allclose(np.asarray(w_alpha), w, rtol=1e-4, atol=1e-5)


def test_ridge_optimum_matches_closed_form(small_problem, oracle):
    """Ridge ERM has the closed form (X^T X / n + lam I) w = X^T y / n."""
    _, w = oracle
    X = np.asarray(small_problem.global_X())
    y = np.asarray(small_problem.global_y())
    n, d = X.shape
    A = X.T @ X / n + small_problem.lam * np.eye(d, dtype=np.float64)
    w_star = np.linalg.solve(A, X.T @ y / n)
    np.testing.assert_allclose(w, w_star, rtol=2e-3, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(st.floats(-5, 5), st.sampled_from([-1.0, 1.0]),
       st.sampled_from(LOSSES))
def test_phi_nonnegative_and_smooth_bound(z, y, loss):
    """Assumption 1/2 sanity: phi >= 0 and |phi'| finite."""
    zz = jnp.float32(z)
    yy = jnp.float32(y)
    val = float(obj.phi(loss, zz, yy))
    assert val >= -1e-6
    grad = float(jax.grad(lambda q: obj.phi(loss, q, yy))(zz))
    assert np.isfinite(grad)
