"""The static analyzer's own contract: every rule fires on its seeded
fixture at the right file:line, pragmas suppress, the repo lints clean
against the checked-in baseline, and the trace-time contracts hold.

Fixture modules live in tests/fixtures/analysis/ -- linted as source,
never imported.  Each violating line carries a ``# VIOLATION`` marker
(twice when one line yields two findings), so expectations live next to
the code they describe instead of as brittle line-number tables here.
"""

import pathlib
import re

import pytest

from repro.analysis import cli, contracts, lint
from repro.analysis.findings import Baseline, Finding

ROOT = pathlib.Path(__file__).resolve().parent.parent
FIXTURES = ROOT / "tests" / "fixtures" / "analysis"

_MARKER = re.compile(r"# VIOLATION")


def marked_lines(path: pathlib.Path) -> dict[int, int]:
    """{line number: expected finding count} from the # VIOLATION markers."""
    out = {}
    for i, text in enumerate(path.read_text().splitlines(), start=1):
        n = len(_MARKER.findall(text))
        if n:
            out[i] = n
    return out


def lint_fixture(name: str, rule: str) -> list[Finding]:
    return lint.lint_paths([FIXTURES / name], root=ROOT, rules=[rule])


# ---------------------------------------------------------------------------
# Layer 1: each rule fires exactly on its fixture's marked lines.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture,rule", [
    ("bad_version_floor.py", "version-floor"),
    ("bad_mesh.py", "mesh-via-make-mesh"),
    ("bad_pallas.py", "pallas-scalar-index"),
    ("bad_host_sync.py", "traced-host-sync"),
    ("bad_donation.py", "jit-donation"),
    ("bad_f64.py", "f64-without-x64"),
    ("bad_registry.py", "registry-hooks"),
    ("bad_serve_typed_errors.py", "typed-errors"),
])
def test_rule_fires_at_marked_lines(fixture, rule):
    expected = marked_lines(FIXTURES / fixture)
    assert expected, f"{fixture} lost its # VIOLATION markers"
    findings = lint_fixture(fixture, rule)
    got: dict[int, int] = {}
    for f in findings:
        assert f.rule == rule
        assert f.path.endswith(fixture), f.path
        got[f.line] = got.get(f.line, 0) + 1
    assert got == expected, (
        f"{fixture}: findings at {got}, markers at {expected}\n"
        + "\n".join(f.format() for f in findings))


def test_all_rules_together_report_only_marked_lines():
    """Running the full default rule set over one fixture must not produce
    cross-rule false positives on the clean lines."""
    findings = lint.lint_paths([FIXTURES / "bad_donation.py"], root=ROOT)
    lines = {f.line for f in findings}
    assert lines == set(marked_lines(FIXTURES / "bad_donation.py"))


def test_pragmas_suppress_everything():
    findings = lint.lint_paths([FIXTURES / "ok_pragmas.py"], root=ROOT)
    assert findings == [], "\n".join(f.format() for f in findings)


def test_host_code_is_not_flagged():
    """The reachability analysis: `host_report` uses the same host-sync
    calls as the traced `step` but is unreachable from any traced root."""
    findings = lint_fixture("bad_host_sync.py", "traced-host-sync")
    assert findings  # the traced ones do fire
    assert all(f.context != "host_report" for f in findings)


def test_finding_format_is_clickable():
    f = lint_fixture("bad_f64.py", "f64-without-x64")[0]
    assert f.format().startswith("tests/fixtures/analysis/bad_f64.py:7: ")


# ---------------------------------------------------------------------------
# The rule registry mirrors the protocol/compressor registry idiom.
# ---------------------------------------------------------------------------


def test_rule_registry():
    rules = lint.available_rules()
    for name in ("version-floor", "mesh-via-make-mesh", "pallas-scalar-index",
                 "traced-host-sync", "jit-donation", "f64-without-x64",
                 "registry-hooks", "typed-errors"):
        assert name in rules
        assert lint.get_rule(name).description
    with pytest.raises(ValueError, match="unknown analysis rule"):
        lint.get_rule("nope")


def test_example_rules_excluded_from_default_set():
    @lint.register_rule("no-print-example")
    class NoPrint(lint.Rule):
        description = "test-only"

        def check(self, module, project):
            return []

    try:
        assert "no-print-example" in lint.available_rules()
        assert "no-print-example" not in lint.default_rules()
    finally:
        del lint._RULES["no-print-example"]


def test_lint_source_snippet_api():
    """The docs-guide entry point: lint an in-memory snippet."""
    findings = lint.lint_source(
        "import jax\nm = jax.sharding.Mesh(None, ('x',))\n",
        rules=["mesh-via-make-mesh"])
    assert [f.line for f in findings] == [2]


# ---------------------------------------------------------------------------
# Baseline: content-based fingerprints + split semantics.
# ---------------------------------------------------------------------------


def test_baseline_fingerprints_survive_line_shifts():
    src = "import jax.numpy as jnp\n\ndef t():\n    return jnp.float64\n"
    shifted = "import jax.numpy as jnp\n\n\n\n\ndef t():\n    return jnp.float64\n"
    a = lint.lint_source(src, path="m.py", rules=["f64-without-x64"])
    b = lint.lint_source(shifted, path="m.py", rules=["f64-without-x64"])
    assert a[0].line != b[0].line
    assert a[0].fingerprint == b[0].fingerprint


def test_baseline_split(tmp_path):
    findings = lint_fixture("bad_f64.py", "f64-without-x64")
    path = tmp_path / "baseline.json"
    Baseline.write(path, findings)
    loaded = Baseline.load(path)
    new, accepted, stale = loaded.split(findings)
    assert (new, len(accepted), stale) == ([], len(findings), set())
    new, accepted, stale = loaded.split([])
    assert new == [] and accepted == [] and len(stale) == len(findings)
    # Missing file == empty baseline: everything is new.
    empty = Baseline.load(tmp_path / "missing.json")
    new, _, _ = empty.split(findings)
    assert len(new) == len(findings)


# ---------------------------------------------------------------------------
# The acceptance bar: repo lints clean, seeded fixtures fail, via the CLI.
# ---------------------------------------------------------------------------


def test_repo_lints_clean_against_checked_in_baseline():
    findings = lint.lint_paths([ROOT / "src"], root=ROOT)
    baseline = Baseline.load(ROOT / "ANALYSIS_BASELINE.json")
    new, accepted, stale = baseline.split(findings)
    assert new == [], "new findings:\n" + "\n".join(f.format() for f in new)
    assert not stale, f"stale baseline entries: {stale}"
    assert accepted, "the baseline should hold the accepted Pallas finding"


def test_cli_exits_nonzero_on_seeded_fixture(tmp_path, capsys):
    rc = cli.main(["--no-contracts", "--baseline",
                   str(tmp_path / "empty.json"),
                   "--paths", str(FIXTURES / "bad_donation.py")])
    assert rc == 1
    out = capsys.readouterr().out
    assert "jit-donation" in out and "bad_donation.py" in out


def test_cli_exits_zero_on_clean_input(tmp_path, capsys):
    rc = cli.main(["--no-contracts", "--baseline",
                   str(tmp_path / "empty.json"),
                   "--paths", str(FIXTURES / "ok_pragmas.py")])
    assert rc == 0
    assert "0 new finding(s)" in capsys.readouterr().out


def test_cli_update_baseline_roundtrip(tmp_path, capsys):
    base = tmp_path / "b.json"
    args = ["--baseline", str(base),
            "--paths", str(FIXTURES / "bad_f64.py"), "--no-contracts"]
    assert cli.main(args + ["--update-baseline"]) == 0
    capsys.readouterr()
    assert cli.main(args) == 0  # accepted now
    assert "1 baseline-accepted" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# Layer 2: the trace-time contracts (the PR-4/5 dispatch story, pinned).
# ---------------------------------------------------------------------------


def test_lockstep_contracts_hold():
    """Pin: lockstep_run_traced stages as ONE scan of length R with zero
    host callbacks, in the jaxpr and in the compiled HLO."""
    results = {r.name: r for r in contracts.check_lockstep_contracts()}
    assert results["lockstep-scan-fusion"].ok, results
    assert results["lockstep-no-host-callbacks"].ok, results


def test_lag_contracts_hold():
    results = {r.name: r for r in contracts.check_lag_contracts()}
    assert results["lag-scan-fusion"].ok, results
    assert results["lag-no-host-callbacks"].ok, results


def test_engine_donation_aliases_buffers():
    """Pin: the engine's donated fused jits carry donor annotations in the
    lowered module AND input-output aliasing in the compiled executable."""
    results = contracts.check_engine_donation()
    assert len(results) == 3
    for r in results:
        assert r.ok, r.format()


def test_sweep_bucket_cache_sharing():
    (r,) = contracts.check_sweep_bucket_sharing()
    assert r.ok, r.format()


def test_callback_scan_helpers_detect_seeded_callback():
    """The IR helpers are not vacuous: a pure_callback IS detected."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    def f(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((), jnp.float32), x)

    jaxpr = jax.make_jaxpr(f)(jnp.float32(0.0))
    assert contracts.callback_primitives(jaxpr)
    hlo = jax.jit(f).lower(jnp.float32(0.0)).compile().as_text()
    assert contracts.hlo_callback_sites(hlo)
