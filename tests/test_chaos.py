"""Self-healing serve layer under injected faults (PR 9).

Pins the fault-tolerance contract end to end, always under a PINNED fault
schedule (:mod:`repro.core.faults` -- never ad-hoc monkeypatching except to
target one specific cell):

* NaN-poisoned cells are masked per-cell: only the poisoned tenant fails
  (typed ``CellDivergenceError``) and every healthy cohort member's stream
  stays bit-identical to its solo ``Session`` run;
* transient faults retry with deterministic backoff and EXACT counter
  accounting; persistent faults quarantine by cohort bisection so only the
  poison request fails;
* deadline overruns requeue the whole batch on the solo lane (typed
  ``JobTimeoutError`` accounting, no tenant fails for being coalesced with
  a slow batch);
* the per-key circuit breaker opens after ``breaker_threshold`` consecutive
  failures, fast-fails while open, and closes through a half-open probe;
* a dead dispatcher (or ``stop(drain=False)``) poisons every unfinished
  stream with ``ServiceStoppedError`` -- no hang, ever;
* a killed checkpointed run resumes bit-identically from its last snapshot;
* the multi-tenant chaos stress: shuffled submissions under the composite
  ``chaos`` schedule, zero hung jobs, zero orphans, exact counters.
"""

import dataclasses
import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import api
from repro.core import baselines, executor, faults
from repro.core.simulate import ClusterModel
from repro.serve import (
    CellDivergenceError,
    CircuitOpenError,
    CoalescePolicy,
    ExperimentService,
    RecoveryPolicy,
    ServiceStoppedError,
    SpecValidationError,
    serve_http,
)

K, D = 4, 256


def _problem_spec(seed=0):
    return api.ProblemSpec("linear_synthetic",
                           {"num_workers": K, "n_per_worker": 48, "d": D,
                            "nnz_per_row": 12, "seed": seed, "lam": 1e-3})


def _cluster(sigma=5.0):
    return ClusterModel(num_workers=K, straggler_sigma=sigma,
                        delay_model="constant")


def _spec(name="t", method=None, seed=0, num_outer=4, eval_every=2, **kw):
    method = method or baselines.cocoa_plus(K, H=8)
    return api.ExperimentSpec(
        name=name, problem=_problem_spec(),
        cluster=_cluster(),
        methods=(api.MethodEntry(method, num_outer),),
        eval_every=eval_every, seed=seed, **kw)


def _policy(**kw):
    kw.setdefault("batch", "map")
    kw.setdefault("shard", "none")
    kw.setdefault("max_wait_s", 0.0)
    kw.setdefault("max_tenant_depth", 8)
    return CoalescePolicy(**kw)


def _recovery(**kw):
    kw.setdefault("backoff_base_s", 0.001)  # keep test retries fast
    return RecoveryPolicy(**kw)


def _solo_events(spec, executor_mode="scan"):
    entry = spec.methods[0]
    sess = api.Session(spec.problem.build(), entry.config, spec.cluster,
                       num_outer=entry.num_outer, seed=spec.seed,
                       eval_every=spec.eval_every, executor=executor_mode)
    events = list(sess.events())
    return events, sess.result()


def _assert_bit_identical(handle, spec):
    solo_events, solo_result = _solo_events(spec)
    assert list(handle.events(timeout=60)) == solo_events
    np.testing.assert_array_equal(handle.result(timeout=60).w, solo_result.w)


# ---------------------------------------------------------------------------
# Divergence masking: one poisoned cell never takes the cohort down.
# ---------------------------------------------------------------------------


class TestDivergenceMasking:
    def test_nan_poison_fails_only_the_poisoned_tenant(self):
        svc = ExperimentService(
            _policy(), recovery=_recovery(),
            fault=faults.get_fault("nan_poison")(seed=3, count=1))
        specs = {t: _spec(seed=i) for i, t in enumerate("abcd")}
        calls = executor.STATS["sweep_calls"]
        handles = {t: svc.submit(t, s) for t, s in specs.items()}
        svc.drain()

        # the poisoned batch genuinely EXECUTED (divergence is real, caught
        # in-graph by the finite certificates, not pre-screened on the host)
        assert executor.STATS["sweep_calls"] == calls + 1
        assert svc.counters["batches"] == 1
        assert svc.counters["batched_requests"] == 4
        assert svc.counters["masked_cells"] == 1
        assert svc.counters["failed"] == 1

        failed = []
        for t, h in handles.items():
            assert h.done()  # zero hung jobs
            try:
                h.result(timeout=1.0)
            except CellDivergenceError as e:
                assert "masked out" in str(e)
                failed.append(t)
        assert len(failed) == 1
        # the deterministic schedule: same seed + key -> same poisoned cell
        expected = faults.get_fault("nan_poison")(seed=3, count=1)
        svc2_cells = expected.poison_cells(
            4, key=_poison_key_of(svc, specs[failed[0]]))
        assert list("abcd")[svc2_cells[0]] == failed[0]
        # every survivor is bit-identical to its solo fault-free Session
        for t, h in handles.items():
            if t not in failed:
                _assert_bit_identical(h, specs[t])

    def test_poison_stream_terminates_with_typed_error(self):
        svc = ExperimentService(
            _policy(), fault=faults.get_fault("nan_poison")(count=1))
        h = svc.submit("a", _spec())
        svc.drain()
        with pytest.raises(CellDivergenceError):
            list(h.events(timeout=5.0))


def _poison_key_of(svc, spec):
    from repro.serve.coalesce import batch_key

    return batch_key(spec, spec.methods[0], policy=svc.policy)


# ---------------------------------------------------------------------------
# Transient retry + quarantine-and-bisect.
# ---------------------------------------------------------------------------


class TestRetryAndBisect:
    def test_transient_fault_retries_with_exact_accounting(self):
        svc = ExperimentService(
            _policy(), recovery=_recovery(max_attempts=3),
            fault=faults.get_fault("transient_executor")(failures=2))
        sa, sb = _spec(seed=0), _spec(seed=1)
        ha, hb = svc.submit("a", sa), svc.submit("b", sb)
        svc.drain()
        # attempts 0 and 1 faulted, attempt 2 succeeded: exactly 2 retries
        assert svc.counters["retries"] == 2
        assert svc.counters["batches"] == 1
        assert svc.counters["failed"] == 0
        assert svc.counters["bisects"] == 0
        _assert_bit_identical(ha, sa)
        _assert_bit_identical(hb, sb)

    def test_exhausted_transient_bisects_then_fails_typed(self):
        svc = ExperimentService(
            _policy(), recovery=_recovery(max_attempts=2),
            fault=faults.get_fault("transient_executor")(failures=99))
        ha, hb = svc.submit("a", _spec(seed=0)), svc.submit("b", _spec(seed=1))
        svc.drain()
        for h in (ha, hb):
            with pytest.raises(faults.TransientExecutorError):
                h.result(timeout=1.0)
        # cohort of 2 (1 retry) bisected into two singletons (1 retry each)
        assert svc.counters["retries"] == 3
        assert svc.counters["bisects"] == 1
        assert svc.counters["quarantined"] == 2
        assert svc.counters["failed"] == 2
        assert svc.counters["batches"] == 0

    def test_bisect_isolates_the_poison_request(self, monkeypatch):
        """A persistent failure tied to ONE cell: bisection quarantines just
        that request; the other three tenants still get bit-identical
        results from their (re-dispatched) sub-cohorts."""
        svc = ExperimentService(_policy(), recovery=_recovery())
        specs = {t: _spec(seed=i) for i, t in enumerate("abcd")}
        poison = dataclasses.replace(specs["c"], seed=7)
        specs["c"] = poison

        import repro.serve.service as service_mod
        orig = service_mod.run_sweep_cells

        def guarded(problem, method, cells, **kw):
            if any(c.seed == 7 for c in cells):
                raise RuntimeError("persistent poison-cell failure")
            return orig(problem, method, cells, **kw)

        monkeypatch.setattr(service_mod, "run_sweep_cells", guarded)
        handles = {t: svc.submit(t, s) for t, s in specs.items()}
        svc.drain()

        with pytest.raises(RuntimeError, match="poison-cell"):
            handles["c"].result(timeout=1.0)
        # [abcd] fails -> [ab] ok, [cd] fails -> [c] quarantined, [d] ok
        assert svc.counters["bisects"] == 2
        assert svc.counters["quarantined"] == 1
        assert svc.counters["failed"] == 1
        assert svc.counters["batches"] == 2
        assert svc.counters["batched_requests"] == 3
        for t in "abd":
            _assert_bit_identical(handles[t], specs[t])


# ---------------------------------------------------------------------------
# Deadlines: overrun batches are requeued solo, never hung or failed.
# ---------------------------------------------------------------------------


class TestDeadlines:
    def test_slow_batch_requeues_everyone_solo(self):
        svc = ExperimentService(
            _policy(),
            recovery=_recovery(batch_deadline_s=0.15),
            fault=faults.get_fault("slow_batch")(delay_s=1.0,
                                                 slow_attempts=1))
        sa, sb = _spec(seed=0), _spec(seed=1)
        ha, hb = svc.submit("a", sa), svc.submit("b", sb)
        svc.drain()
        assert svc.counters["timeouts"] == 1
        assert svc.counters["requeued_solo"] == 2
        assert svc.counters["solo_requests"] == 2
        assert svc.counters["failed"] == 0
        assert svc.counters["batches"] == 0
        # the solo reruns still deliver bit-identical streams
        _assert_bit_identical(ha, sa)
        _assert_bit_identical(hb, sb)

    def test_solo_deadline_fails_with_typed_timeout(self):
        class SlowSolo(faults.FaultModel):
            fault_name = "test-slow-solo"

            def on_dispatch(self, kind, key, attempt):
                if kind == "solo":
                    import time

                    time.sleep(1.0)

        svc = ExperimentService(
            _policy(), recovery=_recovery(solo_deadline_s=0.1),
            fault=SlowSolo())
        # group protocol -> solo lane
        h = svc.submit("a", _spec(method=baselines.acpd(K, D)))
        svc.drain()
        from repro.serve import JobTimeoutError

        with pytest.raises(JobTimeoutError, match="deadline"):
            h.result(timeout=1.0)
        assert svc.counters["timeouts"] == 1
        assert svc.counters["failed"] == 1


# ---------------------------------------------------------------------------
# Circuit breaker.
# ---------------------------------------------------------------------------


class TestCircuitBreaker:
    def test_opens_after_threshold_and_fast_fails(self):
        # Realistic cooldown without wall-clock: the service clock is a
        # ManualClock that never advances, so the circuit stays open.
        from repro.serve.clock import ManualClock

        svc = ExperimentService(
            _policy(),
            recovery=_recovery(max_attempts=1, max_bisect_depth=0,
                               breaker_threshold=2, breaker_cooldown_s=30.0),
            fault=faults.get_fault("compile_failure")(),
            clock=ManualClock())
        for i in range(2):
            h = svc.submit("a", _spec(seed=i))
            svc.drain()
            with pytest.raises(faults.CompileFailureError):
                h.result(timeout=1.0)
        # breaker open: the next submission fast-fails WITHOUT dispatching
        h = svc.submit("a", _spec(seed=9))
        svc.drain()
        with pytest.raises(CircuitOpenError, match="circuit open"):
            h.result(timeout=1.0)
        assert svc.counters["breaker_rejected"] == 1
        assert svc.stats()["breaker"]["open"]  # visible in /stats

    def test_half_open_probe_closes_on_success(self):
        # The cooldown elapses on an advanced ManualClock, not by passing
        # 0.0 (pre-PR-10 idiom) or real-sleeping.
        from repro.serve.clock import ManualClock

        clock = ManualClock()
        svc = ExperimentService(
            _policy(),
            recovery=_recovery(max_attempts=1, max_bisect_depth=0,
                               breaker_threshold=1, breaker_cooldown_s=30.0),
            fault=faults.get_fault("compile_failure")(), clock=clock)
        h = svc.submit("a", _spec())
        svc.drain()
        with pytest.raises(faults.CompileFailureError):
            h.result(timeout=1.0)
        assert svc.stats()["breaker"]["open"]  # open until the cooldown
        clock.advance(30.0)
        # cooldown elapsed; the fault clears; the half-open probe succeeds
        svc.fault = faults.NoFault()
        spec = _spec(seed=1)
        h2 = svc.submit("a", spec)
        svc.drain()
        _assert_bit_identical(h2, spec)
        assert svc.stats()["breaker"] == {"open": [], "half_open": []}
        assert svc.counters["breaker_rejected"] == 0


# ---------------------------------------------------------------------------
# Teardown poison-pill: a dead service never hangs a consumer (satellite 1).
# ---------------------------------------------------------------------------


class TestPoisonPill:
    def test_dispatcher_death_terminates_every_stream(self):
        svc = ExperimentService(_policy(max_wait_s=0.005))

        def boom(*, flush):
            with svc._lock:
                busy = bool(svc._solo or any(svc._pending.values()))
            if busy:
                raise RuntimeError("synthetic dispatcher crash")
            return False

        svc._dispatch_once = boom
        svc.start()
        h = svc.submit("a", _spec())
        # no hang: the consumer gets a typed error, bounded wait
        with pytest.raises(ServiceStoppedError, match="dispatcher thread died"):
            h.result(timeout=30.0)
        with pytest.raises(ServiceStoppedError):
            list(h.events(timeout=30.0))
        assert svc.health()["status"] == "dead"
        # a dead service refuses new work instead of queueing it forever
        with pytest.raises(ServiceStoppedError, match="cannot accept work"):
            svc.submit("a", _spec())
        svc.stop()

    def test_stop_without_drain_poisons_leftovers(self):
        svc = ExperimentService(_policy())
        h = svc.submit("a", _spec())
        svc.stop(drain=False)
        with pytest.raises(ServiceStoppedError, match="before this job ran"):
            h.result(timeout=1.0)
        assert svc.health()["status"] == "dead"
        assert svc.stats()["pending_batched"] == 0


# ---------------------------------------------------------------------------
# Checkpoint/resume through the service.
# ---------------------------------------------------------------------------


class TestCheckpointResume:
    def test_killed_run_resumes_bit_identically(self, tmp_path):
        spec = _spec(num_outer=6, checkpoint_every=2)
        # run 1: the injected kill hits at the start of the round-4 segment,
        # AFTER the round-2 and round-4 snapshots were written
        svc1 = ExperimentService(
            _policy(), checkpoint_dir=str(tmp_path),
            fault=faults.get_fault("worker_crash")(crashes=0, crash_round=4))
        h1 = svc1.submit("a", spec)
        svc1.drain()
        with pytest.raises(faults.WorkerCrashError, match="resume"):
            h1.result(timeout=1.0)
        saved = sorted(p.name for p in tmp_path.rglob("ckpt_*.npz"))
        assert saved == ["ckpt_00000002.npz", "ckpt_00000004.npz"]

        # run 2: a FRESH service (the old one is gone) resumes the run from
        # the last snapshot -- only the final segment executes
        segs = executor.STATS["lockstep_segment_calls"]
        svc2 = ExperimentService(_policy(), checkpoint_dir=str(tmp_path))
        h2 = svc2.submit("a", spec)
        svc2.drain()
        assert executor.STATS["lockstep_segment_calls"] == segs + 1
        result = h2.result(timeout=30.0)

        # bit-identical to a never-interrupted, never-checkpointed session
        plain = dataclasses.replace(spec, checkpoint_every=None)
        solo_events, solo_result = _solo_events(plain)
        np.testing.assert_array_equal(result.w, solo_result.w)
        assert ([r.gap for r in result.records]
                == [r.gap for r in solo_result.records])
        assert list(h2.events(timeout=5.0)) == solo_events

    def test_checkpoint_spec_needs_service_checkpoint_dir(self):
        svc = ExperimentService(_policy())  # no checkpoint_dir
        with pytest.raises(SpecValidationError, match="checkpoint_dir"):
            svc.submit("a", _spec(checkpoint_every=2))

    def test_checkpoint_specs_ride_the_solo_lane(self, tmp_path):
        svc = ExperimentService(_policy(), checkpoint_dir=str(tmp_path))
        svc.submit("a", _spec(checkpoint_every=2))
        svc.submit("b", _spec(seed=1))  # coalescable plain spec
        assert svc.stats()["pending_solo"] == 1
        assert svc.stats()["pending_batched"] == 1
        svc.drain()
        assert svc.counters["solo_requests"] == 1


# ---------------------------------------------------------------------------
# HTTP error contract (satellite 2) + /health.
# ---------------------------------------------------------------------------


@pytest.fixture
def http_service():
    def make(**svc_kw):
        svc = ExperimentService(_policy(), **svc_kw).start()
        server = serve_http(svc, "127.0.0.1", 0)
        threading.Thread(target=server.serve_forever, daemon=True).start()
        return svc, server, f"http://127.0.0.1:{server.server_address[1]}"

    made = []

    def tracked(**svc_kw):
        out = make(**svc_kw)
        made.append(out)
        return out

    yield tracked
    for svc, server, _ in made:
        server.shutdown()
        svc.stop()


class TestHttpErrors:
    def _submit(self, base, spec, tenant="a"):
        body = json.dumps({"tenant": tenant, "spec": spec.to_dict()}).encode()
        req = urllib.request.Request(f"{base}/submit", data=body,
                                     method="POST")
        with urllib.request.urlopen(req, timeout=30) as r:
            return json.loads(r.read())

    def test_validation_error_is_structured_400(self, http_service):
        _, _, base = http_service()
        spec = _spec().to_dict()
        spec["problem"]["kind"] = "nope"
        body = json.dumps({"tenant": "a", "spec": spec}).encode()
        req = urllib.request.Request(f"{base}/submit", data=body,
                                     method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req, timeout=30)
        assert ei.value.code == 400
        payload = json.loads(ei.value.read())
        assert payload["error_type"] == "SpecValidationError"
        assert "linear_synthetic" in payload["message"]
        assert payload["error"] == payload["message"]  # legacy mirror

    def test_divergence_maps_to_422_with_job_id(self, http_service):
        svc, _, base = http_service(
            fault=faults.get_fault("nan_poison")(count=1))
        job = self._submit(base, _spec())
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/events/{job['job_id']}",
                                   timeout=60)
        assert ei.value.code == 422
        payload = json.loads(ei.value.read())
        assert payload["error_type"] == "CellDivergenceError"
        assert payload["job_id"] == job["job_id"]

    def test_unclassified_error_is_structured_500(self, http_service):
        svc, _, base = http_service(
            recovery=_recovery(max_attempts=1, max_bisect_depth=0),
            fault=faults.get_fault("compile_failure")())
        job = self._submit(base, _spec())
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{base}/events/{job['job_id']}",
                                   timeout=60)
        assert ei.value.code == 500
        assert (json.loads(ei.value.read())["error_type"]
                == "CompileFailureError")

    def test_health_and_fault_counters_in_stats(self, http_service):
        svc, _, base = http_service(
            fault=faults.get_fault("nan_poison")(count=1))
        with urllib.request.urlopen(f"{base}/health", timeout=30) as r:
            health = json.loads(r.read())
        assert health["status"] == "ok" and health["dispatcher_alive"]
        job = self._submit(base, _spec())
        svc.job(job["job_id"])._done.wait(timeout=60)
        with urllib.request.urlopen(f"{base}/stats", timeout=30) as r:
            stats = json.loads(r.read())
        assert stats["fault_model"] == "nan_poison"
        assert stats["masked_cells"] == 1
        for k in ("retries", "bisects", "timeouts", "breaker_rejected",
                  "requeued_solo", "quarantined"):
            assert k in stats
        assert stats["breaker"] == {"open": [], "half_open": []}


# ---------------------------------------------------------------------------
# The multi-tenant chaos stress (satellite 3).
# ---------------------------------------------------------------------------


class TestChaosStress:
    def test_shuffled_tenants_under_composite_chaos_schedule(self):
        """8 jobs from 4 tenants, submission order shuffled by a pinned rng,
        under the composite ``chaos`` schedule (one deadline overrun, one
        transient fault, one NaN cell): zero hung jobs, zero orphans, only
        the poisoned tenant fails, survivors bit-identical, exact counters.
        """
        svc = ExperimentService(
            _policy(max_batch=4),
            recovery=_recovery(max_attempts=3, batch_deadline_s=0.15),
            fault=faults.get_fault("chaos")(seed=5, delay_s=1.0, poison=1))
        jobs = [(f"tenant{i % 4}", _spec(seed=i)) for i in range(8)]
        rng = np.random.default_rng(123)  # pinned interleaving
        order = rng.permutation(len(jobs))
        handles = {}
        for i in order:
            tenant, spec = jobs[i]
            handles[i] = (svc.submit(tenant, spec), spec)
        svc.drain()

        # zero hung jobs: every handle reaches a terminal state, bounded
        failed = {}
        for i, (h, spec) in handles.items():
            assert h.done()
            try:
                h.result(timeout=60.0)
            except Exception as e:  # analysis: fail-fast-ok (collected and asserted typed below)
                failed[i] = e
        # exactly the one poisoned cell fails, with the typed error
        assert len(failed) == 1
        assert isinstance(next(iter(failed.values())), CellDivergenceError)
        # survivors are bit-identical to their solo fault-free Sessions
        for i, (h, spec) in handles.items():
            if i not in failed:
                _assert_bit_identical(h, spec)

        # exact schedule accounting: batch 1 of 4 overran the deadline and
        # was requeued solo; batch 2 of 4 faulted transiently once, retried,
        # then delivered 3 of its 4 cells (1 masked)
        c = svc.counters
        assert c["submitted"] == 8
        assert c["timeouts"] == 1 and c["requeued_solo"] == 4
        assert c["retries"] == 1
        assert c["batches"] == 1 and c["batched_requests"] == 4
        assert c["solo_requests"] == 4
        assert c["failed"] == 1 and c["masked_cells"] == 1
        assert c["bisects"] == 0 and c["quarantined"] == 0
        assert c["breaker_rejected"] == 0
        # zero orphans: all depth released, nothing pending anywhere
        stats = svc.stats()
        assert stats["inflight_by_tenant"] == {}
        assert stats["pending_batched"] == 0 and stats["pending_solo"] == 0
        # the schedule replays: a fresh instance produces the same decisions
        assert (faults.get_fault("chaos")(seed=5, delay_s=1.0, poison=1)
                .spec() == svc.fault.spec())
