"""GroupedDeltaExchange invariants (the deep-net ACPD integration)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import exchange as ex


def _grads(key, G, shapes):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, (G, *s))
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_dense_config_equals_mean_gradient():
    """B=K, rho=1, gamma=1 must reproduce plain data-parallel averaging."""
    G = 4
    cfg = ex.dense_config(G)
    grads = _grads(jax.random.key(0), G, [(64,), (8, 16)])
    params = {k: jnp.zeros(v.shape[1:]) for k, v in grads.items()}
    state = ex.init_state(cfg, params)
    update, new_state, metrics = ex.exchange(cfg, grads, state, jnp.int32(0))
    for k in grads:
        np.testing.assert_allclose(np.asarray(update[k]),
                                   np.asarray(jnp.mean(grads[k], axis=0)),
                                   rtol=1e-6, atol=1e-7)
        assert float(jnp.abs(new_state.residual[k]).max()) == 0.0


def test_error_feedback_conservation():
    """gamma^-1 * B * update + sum(residual_new) == sum(residual_old + grads)
    over participating groups; skipped groups accumulate untouched."""
    G, B = 8, 3
    cfg = ex.ExchangeConfig(num_groups=G, group_size=B, sync_period=1000,
                            rho=0.1, gamma=0.7, min_leaf_size=8)
    grads = _grads(jax.random.key(1), G, [(4096,)])
    params = {"p0": jnp.zeros(4096)}
    state = ex.init_state(cfg, params)
    state = ex.ExchangeState(residual=jax.tree.map(
        lambda r: r + 0.1, state.residual))  # nonzero starting residual
    step = jnp.int32(3)
    update, new_state, _ = ex.exchange(cfg, grads, state, step)
    p = np.asarray(ex.participation(cfg, step))
    dw = np.asarray(state.residual["p0"]) + np.asarray(grads["p0"])
    # conservation: participating groups' (sent + residual) == dw
    sent_total = np.asarray(update["p0"]) * p.sum() / cfg.gamma
    res_new = np.asarray(new_state.residual["p0"])
    recon = sent_total + (res_new * p[:, None]).sum(0)
    np.testing.assert_allclose(recon, (dw * p[:, None]).sum(0), rtol=1e-4,
                               atol=1e-5)
    # skipped groups keep accumulating exactly
    for g in range(G):
        if p[g] == 0:
            np.testing.assert_allclose(res_new[g], dw[g], rtol=1e-6, atol=1e-7)


def test_participation_covers_all_groups():
    cfg = ex.ExchangeConfig(num_groups=8, group_size=3, sync_period=100)
    seen = np.zeros(8, bool)
    for t in range(8):
        seen |= np.asarray(ex.participation(cfg, jnp.int32(t))) > 0
    assert seen.all()


def test_dense_sync_every_T():
    cfg = ex.ExchangeConfig(num_groups=4, group_size=1, sync_period=5, rho=0.01)
    grads = _grads(jax.random.key(2), 4, [(512,)])
    params = {"p0": jnp.zeros(512)}
    state = ex.init_state(cfg, params)
    _, state, m0 = ex.exchange(cfg, grads, state, jnp.int32(0))
    assert float(m0["exchange/dense_step"]) == 0.0
    _, state, m4 = ex.exchange(cfg, grads, state, jnp.int32(4))
    assert float(m4["exchange/dense_step"]) == 1.0
    # after a dense step every residual is flushed
    assert float(jnp.abs(state.residual["p0"]).max()) == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(256, 4096), st.floats(0.002, 0.3),
       st.integers(0, 2**31 - 1))
def test_threshold_topk_calibration(n, rho, seed):
    """Histogram threshold keeps k'/k in [1, 1.25] on continuous data."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    k = max(1, int(rho * n))
    t = ex.threshold_for_topk(x, jnp.int32(k))
    kept = int(jnp.sum(jnp.abs(x) >= t))
    assert kept >= k
    assert kept <= max(k + 2, int(1.25 * k))
