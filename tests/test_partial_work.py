"""partial_work chunk streaming + elastic membership: unit pins.

The protocol-wide contracts (clock monotonicity, byte formulas, scan parity
across the registry) live in ``test_protocol_invariants.py``; this module
pins the partial_work-specific behaviors:

* ``n_chunks=1`` degrades BIT-FOR-BIT to the ``group`` protocol it extends;
* chunk conservation under ``constant`` delays: every billed chunk is
  harvested exactly once (the final T-barrier drains the queue), so
  ``sum(arrivals) * wire_bytes == bytes_up`` -- the closed-form total;
* ``pw_quantum`` harvest ticks advance the server clock by exactly the
  quantum between non-barrier rounds;
* elasticity: a dropout can never hang the B-of-K barrier (including the
  whole-cluster dropout worst case), a rejoin re-enters the RNG stream
  deterministically (same spec + seed => identical trajectory), and a
  dropped worker's bytes stop accruing;
* routing: membership / pw_quantum force the event loop, partial_work rides
  the serve layer's solo lane, and non-supporting protocols reject a
  membership schedule loudly.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro import api
from repro.api.problems import ProblemSpec
from repro.api.spec import ExperimentSpec, MethodEntry
from repro.api import sweep as sweep_lib
from repro.core import baselines
from repro.core import compress as compress_lib
from repro.core import engine
from repro.core import executor as executor_lib
from repro.core.simulate import ClusterModel

K, D, H, T = 4, 48, 8, 4


def _problem():
    return ProblemSpec("linear_synthetic",
                       {"num_workers": K, "n_per_worker": 24, "d": D,
                        "nnz_per_row": 6, "seed": 3, "lam": 1e-2,
                        "loss": "ridge"})


def _cluster(delay="constant", params=(), membership=()):
    return ClusterModel(num_workers=K, straggler_sigma=3.0,
                        delay_model=delay, delay_params=tuple(params),
                        membership=tuple(membership))


def _pw(n_chunks=2, pw_quantum=None, rho_d=8):
    return baselines.acpd_partial_work(K, D, B=2, T=T, rho_d=rho_d, H=H,
                                       n_chunks=n_chunks,
                                       pw_quantum=pw_quantum)


def _spec(cfg, cluster, *, num_outer=2, seed=0, executor="auto"):
    return ExperimentSpec(name=f"pw-{cfg.name}", problem=_problem(),
                          cluster=cluster,
                          methods=(MethodEntry(cfg, num_outer),),
                          eval_every=num_outer * T, seed=seed,
                          executor=executor).validate()


def _run(spec):
    """Drain one session; returns (session, RoundEvents, SyncEvent iters)."""
    session = api.Experiment(spec).session(spec.methods[0])
    rounds, syncs = [], set()
    for ev in session.events():
        if isinstance(ev, api.RoundEvent):
            rounds.append(ev)
        elif isinstance(ev, api.SyncEvent):
            syncs.add(ev.iteration)
    return session, rounds, syncs


def _assert_identical(a, b):
    assert len(a.records) == len(b.records)
    for ra, rb in zip(a.records, b.records):
        for f in dataclasses.fields(ra):
            va, vb = getattr(ra, f.name), getattr(rb, f.name)
            assert va == vb, (f.name, va, vb)
    assert np.array_equal(np.asarray(a.w), np.asarray(b.w))
    assert np.array_equal(np.asarray(a.alpha), np.asarray(b.alpha))


# ---------------------------------------------------------------------------
# Chunked arrivals.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("delay,params", [
    ("constant", ()),
    ("shifted_exponential", (("tail_mean", 0.8),)),
    ("markov", (("p_slow", 0.2), ("p_recover", 0.5), ("slow_factor", 4.0))),
])
def test_one_chunk_degrades_to_group_bitwise(delay, params):
    """n_chunks=1 is the group protocol, bit-for-bit: same records, same
    final arrays, same per-round arrivals/bytes, under every delay family
    (vector-sampled, stateful, deterministic)."""
    group_cfg = baselines.acpd(K, D, B=2, T=T, rho_d=8, H=H)
    pw_cfg = dataclasses.replace(_pw(n_chunks=1), rho=group_cfg.rho)
    runs, rounds = {}, {}
    for name, cfg in (("group", group_cfg), ("pw", pw_cfg)):
        spec = _spec(cfg, _cluster(delay, params), executor="event")
        session, revs, _ = _run(spec)
        runs[name], rounds[name] = session.result(), revs
    _assert_identical(runs["group"], runs["pw"])
    for eg, ep in zip(rounds["group"], rounds["pw"]):
        assert (eg.arrivals, eg.bytes_up, eg.bytes_down, eg.sim_time) == \
               (ep.arrivals, ep.bytes_up, ep.bytes_down, ep.sim_time)


@pytest.mark.parametrize("n_chunks", [2, 4])
def test_constant_delay_chunk_conservation(n_chunks):
    """Closed-form harvest total under constant delays: every billed chunk
    is harvested exactly once, except the final barrier's relaunch wave.
    The run ends on a T-barrier, which drains every in-flight chunk (so all
    K workers complete) and then relaunches all K chunked passes; those
    K * n_chunks chunks are the ONLY launches never harvested.  Hence
    ``(sum(arrivals) + K * n_chunks) * wire == bytes_up``, exactly.
    Constant delays are RNG-free, so the arrival sequence is
    seed-independent."""
    cfg = _pw(n_chunks=n_chunks)
    wire = compress_lib.for_method(cfg, D).wire_bytes(D)
    seq = {}
    for seed in (0, 11):
        _, rounds, _ = _run(_spec(cfg, _cluster(), seed=seed,
                                  executor="event"))
        total = sum(ev.arrivals for ev in rounds)
        assert (total + K * n_chunks) * wire == rounds[-1].bytes_up
        seq[seed] = [ev.arrivals for ev in rounds]
    assert seq[0] == seq[11]  # deterministic: no RNG in the timing path


def test_quantum_ticks_advance_clock_exactly():
    """pw_quantum mode: every non-barrier round's server clock advances by
    exactly the quantum (the fixed harvest tick); barriers jump to the
    drained arrival max."""
    q = 2.5e-3
    spec = _spec(_pw(pw_quantum=q), _cluster(), executor="auto")
    session, rounds, syncs = _run(spec)
    assert session.executor == "event"  # quantum mode is event-only
    prev = 0.0
    for ev in rounds:
        if ev.iteration in syncs:
            assert ev.sim_time >= prev
        else:
            assert ev.sim_time == pytest.approx(prev + q, abs=0.0)
        prev = ev.sim_time


# ---------------------------------------------------------------------------
# Elasticity.
# ---------------------------------------------------------------------------


def _timescale():
    """(mid, late) sim-times of the membership-free reference run."""
    _, rounds, _ = _run(_spec(_pw(), _cluster(), executor="event"))
    return rounds[len(rounds) // 3].sim_time, rounds[-1].sim_time


def test_dropout_never_hangs_barrier():
    """A worker dropping mid-run (never rejoining) shrinks the B-of-K
    deadline instead of hanging it: the session still completes every
    scheduled round, monotonically."""
    t_mid, _ = _timescale()
    spec = _spec(_pw(), _cluster(membership=((1, t_mid, None),)),
                 num_outer=2, executor="event")
    session, rounds, _ = _run(spec)
    assert len(rounds) == 2 * T  # every round ran; nothing hung
    assert all(b.sim_time >= a.sim_time
               for a, b in zip(rounds, rounds[1:]))
    session.result()  # finalized


def test_whole_cluster_dropout_is_starvation_not_deadlock():
    """Worst case: EVERY worker drops and never rejoins.  Remaining rounds
    become no-ops (zero arrivals) rather than a hang, and accounting
    freezes."""
    t_mid, _ = _timescale()
    membership = tuple((k, t_mid, None) for k in range(K))
    spec = _spec(_pw(), _cluster(membership=membership), executor="event")
    _, rounds, _ = _run(spec)
    assert len(rounds) == 2 * T
    assert rounds[-1].arrivals == 0  # starved tail rounds are no-ops
    frozen = [ev for ev in rounds if ev.arrivals == 0]
    assert frozen, "whole-cluster dropout never starved a round"
    assert frozen[-1].bytes_up == frozen[0].bytes_up


def test_rejoin_is_deterministic_and_reenters_rng_stream():
    """Same spec + seed => identical trajectory THROUGH a drop/rejoin cycle
    (the rejoin re-enters the launch RNG stream at a deterministic point),
    and the rejoined worker demonstrably works again: more bytes than the
    never-rejoins variant of the same schedule."""
    t_mid, t_late = _timescale()
    rejoin = _cluster(delay="shifted_exponential", params=(("tail_mean", 0.8),),
                      membership=((1, t_mid, 0.6 * t_late),))
    results = []
    for _ in range(2):
        session, rounds, _ = _run(_spec(_pw(), rejoin, num_outer=2,
                                        executor="event"))
        results.append((session.result(), rounds))
    _assert_identical(results[0][0], results[1][0])
    for ea, eb in zip(results[0][1], results[1][1]):
        assert (ea.sim_time, ea.arrivals, ea.bytes_up) == \
               (eb.sim_time, eb.arrivals, eb.bytes_up)
    gone = dataclasses.replace(rejoin, membership=((1, t_mid, None),))
    _, rounds_gone, _ = _run(_spec(_pw(), gone, num_outer=2,
                                   executor="event"))
    assert rounds_gone[-1].bytes_up < results[0][1][-1].bytes_up


def test_dropped_worker_bytes_stop_accruing():
    """With worker 1 dropped forever, total uplink bytes fall strictly below
    the full-strength run, and the deficit is a whole number of chunk
    messages (truncated passes roll back to the last SENT chunk; nothing is
    half-billed)."""
    t_mid, _ = _timescale()
    cfg = _pw()
    wire = compress_lib.for_method(cfg, D).wire_bytes(D)
    _, full, _ = _run(_spec(cfg, _cluster(), executor="event"))
    _, dropped, _ = _run(_spec(cfg, _cluster(membership=((1, t_mid, None),)),
                               executor="event"))
    assert dropped[-1].bytes_up < full[-1].bytes_up
    assert dropped[-1].bytes_up % wire == 0


# ---------------------------------------------------------------------------
# Routing: executor / sweep / serve lanes.
# ---------------------------------------------------------------------------


def test_membership_and_quantum_force_event_loop():
    ok, why = executor_lib.scan_supported(
        _pw(), _cluster(membership=((1, 1e-3, None),)))
    assert not ok and "membership" in why
    ok, why = executor_lib.scan_supported(_pw(pw_quantum=1e-3), _cluster())
    assert not ok and "quantum" in why


def test_partial_work_declines_sweep_and_coalesce():
    ok, why = sweep_lib.sweep_supported(_pw(), _cluster())
    assert not ok and "sweep" in why
    ok, why = executor_lib.coalesce_supported(_pw(), _cluster())
    assert not ok and "chunk" in why
    ok, why = executor_lib.coalesce_supported(
        baselines.acpd_hierarchical(K, D, T=T, rho_d=8, H=H), _cluster())
    assert not ok and why


def test_membership_rejected_by_nonsupporting_protocols():
    cluster = _cluster(membership=((1, 1e-3, None),))
    cfg = baselines.acpd(K, D, B=2, T=T, rho_d=8, H=H)
    with pytest.raises(ValueError, match="membership"):
        _spec(cfg, cluster).validate()
    with pytest.raises(ValueError, match="supports_membership"):
        api.Experiment(dataclasses.replace(
            _spec(_pw(), cluster), methods=(MethodEntry(cfg, 1),)
        )).session(MethodEntry(cfg, 1))


def test_membership_schedule_validation():
    bad = [((9, 1e-3, None), "worker 9"),
           ((1, -1.0, None), "drop time"),
           ((1, 2e-3, 1e-3), "rejoin time")]
    for entry, match in bad:
        with pytest.raises(ValueError, match=match):
            _spec(_pw(), _cluster(membership=(entry,)))


def test_hierarchical_b_rack_quota():
    """Two racks, rack_b=1: every non-barrier round waits for at least one
    arrival from EACH rack, so arrivals >= n_racks * rack_b."""
    cfg = baselines.acpd_hierarchical(K, D, T=T, rho_d=8, H=H,
                                      n_racks=2, rack_b=1)
    spec = _spec(cfg, _cluster(), executor="event")
    _, rounds, syncs = _run(spec)
    assert len(rounds) == 2 * T
    for ev in rounds:
        if ev.iteration not in syncs:
            assert ev.arrivals >= 2, ev
