"""The unified Compressor registry (core/compress.py): round-trip properties
over duplicate-magnitude and bf16 inputs, legacy-mapping resolution, and the
one-way byte accounting shared by the simulator (filter.py path) and the
transformer exchange path (exchange.py)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import compress as cp
from repro.core import exchange as ex
from repro.core import filter as flt
from repro.core.acpd import MethodConfig


def test_registry_contents_and_errors():
    names = cp.available_compressors()
    for expected in ("dense", "topk_exact", "topk_threshold", "topk_q8"):
        assert expected in names
    with pytest.raises(ValueError, match="unknown compressor"):
        cp.get_compressor("nope")
    with pytest.raises(ValueError, match="unknown compressor"):
        ex.ExchangeConfig(num_groups=2, group_size=1, compressor="nope")


def _with_duplicates(rng, d):
    """A vector whose magnitudes contain deliberate ties."""
    base = rng.standard_normal(max(2, (d + 1) // 2)).astype(np.float32)
    dup = np.concatenate([base, -base])[:d]  # |x| duplicated pairwise
    rng.shuffle(dup)
    return dup


@settings(max_examples=25, deadline=None)
@given(st.integers(8, 300), st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_topk_roundtrip_with_duplicate_magnitudes(d, k_div, seed):
    """sent + residual == dw bitwise even when magnitudes tie."""
    rng = np.random.default_rng(seed)
    dw = jnp.asarray(_with_duplicates(rng, d))
    k = max(1, d // k_div)
    for comp in (cp.TopKExact(k=k), cp.TopKThreshold(k=k), cp.Dense()):
        sent, residual = comp.compress(dw)
        assert bool(jnp.all(sent + residual == dw)), comp
    # exact-k keeps exactly k even under ties; threshold keeps >= k
    sent, _ = cp.TopKExact(k=k).compress(dw)
    assert int(jnp.sum(sent != 0)) <= k  # zeros in dw may reduce the nnz
    sent_t, _ = cp.TopKThreshold(k=k).compress(dw)
    mag = jnp.abs(dw)
    c_k = jnp.sort(mag)[-k]
    assert bool(jnp.all((sent_t != 0) == ((mag >= c_k) & (dw != 0))))


@settings(max_examples=15, deadline=None)
@given(st.integers(16, 256), st.integers(0, 2**31 - 1))
def test_topk_roundtrip_bf16(d, seed):
    """bf16 payloads: masking is exact, so conservation holds bitwise."""
    rng = np.random.default_rng(seed)
    dw = jnp.asarray(rng.standard_normal(d), jnp.bfloat16)
    k = max(1, d // 4)
    for comp in (cp.TopKExact(k=k), cp.Dense()):
        sent, residual = comp.compress(dw)
        assert sent.dtype == jnp.bfloat16
        assert bool(jnp.all(sent + residual == dw)), comp


@settings(max_examples=15, deadline=None)
@given(st.integers(32, 400), st.integers(0, 2**31 - 1))
def test_quantized_error_feedback(d, seed):
    """topk_q8: dequantized payload within half a quant step of the exact
    top-k payload; the quantization error lands in the residual (lossless
    over time via error feedback)."""
    rng = np.random.default_rng(seed)
    dw = jnp.asarray(rng.standard_normal(d).astype(np.float32))
    k = max(1, d // 8)
    comp = cp.QuantizedTopK(k=k)
    sent, residual = comp.compress(dw)
    exact = flt.topk_mask_exact(dw, k)
    # conservation: nothing is lost, only delayed (up to fp rounding of the
    # dequantized payload)
    np.testing.assert_allclose(np.asarray(sent + residual), np.asarray(dw),
                               rtol=1e-6, atol=1e-6)
    scale = float(jnp.max(jnp.abs(exact.sent))) / 127.0
    err = np.abs(np.asarray(sent) - np.asarray(exact.sent))
    assert err.max() <= 0.5 * scale + 1e-7
    # the payload is strictly smaller on the wire than plain top-k
    assert comp.wire_bytes(d) < cp.TopKExact(k=k).wire_bytes(d)


def test_wire_bytes_match_filter_module():
    """The registry's byte formulas ARE filter.py's Table-I accounting."""
    d, k = 47_236, 1000
    assert cp.TopKExact(k=k).wire_bytes(d) == flt.message_bytes(k)
    assert cp.TopKThreshold(k=k).wire_bytes(d) == flt.message_bytes(k)
    assert cp.Dense().wire_bytes(d) == flt.dense_bytes(d)
    assert cp.QuantizedTopK(k=k).wire_bytes(d) == k * 5 + 4


def test_for_method_reproduces_legacy_mapping():
    d = 1024
    dense = cp.for_method(MethodConfig(name="m", rho=1.0), d)
    assert isinstance(dense, cp.Dense)
    exact = cp.for_method(MethodConfig(name="m", rho=0.1), d)
    assert isinstance(exact, cp.TopKExact)
    assert exact.k == flt.num_kept(d, 0.1)
    thresh = cp.for_method(MethodConfig(name="m", rho=0.1, use_exact_k=False), d)
    assert isinstance(thresh, cp.TopKThreshold)
    q8 = cp.for_method(MethodConfig(name="m", rho=0.1, compressor="topk_q8"), d)
    assert isinstance(q8, cp.QuantizedTopK)
    assert q8.k == flt.num_kept(d, 0.1)


def test_for_exchange_respects_refine():
    """ExchangeConfig.refine reaches every histogram-based compressor."""
    for name in ("topk_threshold", "topk_q8"):
        cfg = ex.ExchangeConfig(num_groups=2, group_size=1, rho=0.05,
                                refine=False, compressor=name)
        assert cp.for_exchange(cfg).refine is False, name


def test_exchange_and_simulator_byte_accounting_agree(small_problem):
    """Acceptance pin: filter.py-path (engine) and exchange.py-path bytes go
    through the SAME registry objects and agree exactly."""
    from repro.core import engine
    from repro.core.simulate import ClusterModel

    d = small_problem.d
    rho = 32 / d
    k = flt.num_kept(d, rho)

    # Simulator side: the group protocol bills comp.wire_bytes per upload.
    m = MethodConfig(name="ACPD", protocol="group", B=2, T=5, rho=rho, H=8)
    proto = engine.get_protocol("group")(
        small_problem, m, ClusterModel(num_workers=small_problem.num_workers),
        seed=0)
    assert proto.up_bytes == proto.comp.wire_bytes(d) == flt.message_bytes(k)

    # Exchange side: one step with the exact-k compressor sends exactly k
    # entries per participating group -- billed with the same formula.
    G, B = 4, 2
    n_leaf = 512
    cfg = ex.ExchangeConfig(num_groups=G, group_size=B, sync_period=1000,
                            rho=k / n_leaf, min_leaf_size=8,
                            compressor="topk_exact")
    comp_ex = cp.for_exchange(cfg)
    grads = {"p0": jnp.asarray(
        np.random.default_rng(0).standard_normal((G, n_leaf)), jnp.float32)}
    state = ex.init_state(cfg, {"p0": jnp.zeros(n_leaf)})
    _, _, metrics = ex.exchange(cfg, grads, state, jnp.int32(0))
    expected = B * int(comp_ex.payload_bytes(k))
    assert int(metrics["exchange/bytes_step"]) == expected
    # ...and that per-message cost equals the simulator's wire bytes for the
    # same (d, k): ONE formula across both paths.
    assert int(comp_ex.payload_bytes(k)) == flt.message_bytes(k) \
        == cp.TopKExact(k=k).wire_bytes(n_leaf)


def test_quantized_compressor_runs_in_engine(small_problem):
    """MethodConfig.compressor='topk_q8': converges and uploads fewer bytes
    than the 8-bytes-per-entry top-k run (same k)."""
    from repro.core.acpd import run_method
    from repro.core.simulate import ClusterModel

    K, d = small_problem.num_workers, small_problem.d
    cluster = ClusterModel(num_workers=K)
    base = MethodConfig(name="topk", protocol="group", B=2, T=10, rho=64 / d,
                        gamma=0.5, H=256)
    q8 = dataclasses.replace(base, name="q8", compressor="topk_q8")
    res_b = run_method(small_problem, base, cluster, num_outer=4,
                       eval_every=4, seed=2)
    res_q = run_method(small_problem, q8, cluster, num_outer=4,
                       eval_every=4, seed=2)
    assert res_q.records[-1].bytes_up < res_b.records[-1].bytes_up
    gaps = [r.gap for r in res_q.records]
    assert gaps[-1] < gaps[0] / 5, gaps
